"""Inference engine: prefill/serve/decode-chunk factories and host generate.

``prefill_step`` and ``serve_step`` are the two programs the dry-run lowers
for the inference cells (prefill_32k → prefill_step; decode_32k / long_500k
→ serve_step).  Both are pure functions of (params, inputs, caches) so the
tenancy layer can AOT-compile them per (arch × shape × lease size) — the
TPU-side "instruction frame package".

The serving hot path is **chunked and donated**:

* :func:`make_decode_chunk` fuses ``n_steps`` decode iterations into one
  ``lax.scan`` program with on-device slot bookkeeping (:class:`SlotState`:
  active mask, per-slot positions, EOS/max-token detection inside the scan),
  so a batcher issues one device dispatch and one host sync per chunk
  instead of per token.
* Callers jit these programs with ``donate_argnums`` on the cache/state
  arguments so XLA updates the ring-buffer KV in place; without donation
  every token would copy the entire cache tree (the dominant decode-bytes
  term).  A donated input buffer is dead after the call — owners must adopt
  the returned tree (see ``ContinuousBatcher``).
* :func:`make_admit_step` fuses prefill + per-slot scatter admission into
  one donated program (see ``serving.batcher`` for the slot protocol).
* The vocab-padding mask is built **once** per (vocab, padded) pair
  (:meth:`ServeConfig.logit_mask`) and applied as a fused additive mask,
  instead of rebuilding a full-logits ``.at[..., vocab:].set(-inf)`` copy on
  every step.

Invariant: a slot that deactivates mid-chunk (EOS or token budget) keeps
decoding with its position frozen — it overwrites its *own* ring slot with
dead values, which is safe because admission re-seeds the slot's cache from
prefill before it is reused.
"""

from __future__ import annotations

import dataclasses
import functools
from collections import OrderedDict
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, encoder_forward, prefill, prefix_prefill
from repro.models.attention import check_attn_impl
from repro.models.transformer import Caches

from .kv_cache import pages_for


@functools.lru_cache(maxsize=32)
def _logit_mask(vocab: int, vocab_padded: int):
    """Additive mask (Vp,) — 0 on the real vocab, -inf on padding.  Built
    once and closed over by the step functions (a hoisted jit constant),
    replacing the per-step full-logits ``.set(-inf)`` copy."""
    if vocab_padded <= vocab:
        return None
    m = np.zeros((vocab_padded,), np.float32)
    m[vocab:] = -np.inf
    return jnp.asarray(m)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int
    attn_impl: str = "xla"       # see models.attention.ATTN_CAPABILITIES
    greedy: bool = True
    temperature: float = 1.0
    chunk: int = 8               # max decode steps fused per device dispatch

    def __post_init__(self):
        # fail at config construction, not three layers into a jit trace;
        # mode-specific checks (paged/prefix/sliding_window) happen where
        # the mode is known — ContinuousBatcher.__init__
        check_attn_impl(self.attn_impl, "dense")

    def logit_mask(self, cfg):
        return _logit_mask(cfg.vocab, cfg.vocab_padded)


def chunk_bucket(n: int) -> int:
    """Largest power of two ≤ n — the fixed set of chunk/prefill shapes the
    jit cache may hold (log2 many programs, no per-request recompiles)."""
    return 1 << (max(n, 1).bit_length() - 1)


def select_token(logits, mask, scfg: ServeConfig, key):
    """Greedy or sampled next-token selection under the vocab-padding mask."""
    if mask is not None:
        logits = logits + mask.astype(logits.dtype)
    if scfg.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / scfg.temperature, axis=-1
    ).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Single-step programs (AOT surface for cells.py / tenancy)
# ---------------------------------------------------------------------------


def make_prefill_step(cfg, scfg: ServeConfig, *, policy=None):
    """prefill_step(params, batch) -> (last-token logits, Caches).

    batch: {"tokens": (B, S)} + family extras (extra_embeds/positions/frames).
    """

    def prefill_step(params, batch):
        kw: Dict[str, Any] = dict(impl=scfg.attn_impl, policy=policy)
        if cfg.family == "vlm":
            kw["extra_embeds"] = batch["extra_embeds"]
            kw["positions"] = batch["positions"]
        if cfg.family == "audio":
            kw["enc_out"] = encoder_forward(
                params, batch["frames"], cfg, impl=scfg.attn_impl, policy=policy
            )
        return prefill(params, batch["tokens"], cfg, max_len=scfg.max_len, **kw)

    return prefill_step


def make_serve_step(cfg, scfg: ServeConfig, *, policy=None):
    """serve_step(params, tokens (B,), caches, cur_pos (B,), key) ->
    (next_tokens (B,), logits, caches)."""
    mask = scfg.logit_mask(cfg)

    def serve_step(params, tokens, caches: Caches, cur_pos, key):
        logits, caches = decode_step(
            params, tokens, caches, cur_pos, cfg, impl=scfg.attn_impl,
            policy=policy,
        )
        if mask is not None:
            logits = logits + mask.astype(logits.dtype)
        nxt = select_token(logits, None, scfg, key)
        return nxt, logits, caches

    return serve_step


# ---------------------------------------------------------------------------
# Chunked decode with on-device slot bookkeeping
# ---------------------------------------------------------------------------


class SlotState(NamedTuple):
    """Per-slot decode bookkeeping, resident on device between dispatches.

    tokens:     (B,) int32 — last emitted token (next decode input)
    cur_pos:    (B,) int32 — absolute position the next token writes to
    active:     (B,) bool  — slot is mid-generation
    remaining:  (B,) int32 — decode tokens left until the slot's max budget
    eos:        (B,) int32 — per-slot EOS id, -1 = none
    """

    tokens: jax.Array
    cur_pos: jax.Array
    active: jax.Array
    remaining: jax.Array
    eos: jax.Array


def init_slot_state(batch: int) -> SlotState:
    return SlotState(
        tokens=jnp.zeros((batch,), jnp.int32),
        cur_pos=jnp.zeros((batch,), jnp.int32),
        active=jnp.zeros((batch,), bool),
        remaining=jnp.zeros((batch,), jnp.int32),
        eos=jnp.full((batch,), -1, jnp.int32),
    )


def make_decode_chunk(cfg, scfg: ServeConfig, n_steps: int, *, policy=None):
    """decode_chunk(params, caches, state, key) ->
    (caches, state, tokens (T, B), emitted (T, B), poisoned (B,)).

    One ``lax.scan`` over ``n_steps`` decode iterations.  EOS and
    token-budget detection happen inside the scan: a slot that finishes
    deactivates immediately, its position freezes, and later iterations
    emit nothing for it (``emitted`` is the validity mask).

    ``poisoned`` is the fault sentinel: a slot whose logits come back
    non-finite (NaN/inf — a corrupted cache page, a bad reduction) is
    deactivated *before* its token is selected or emitted, so a poisoned
    value never enters any output stream — the blast radius is the slot.
    The host requeues the flagged request (see ``ContinuousBatcher``).
    Jit this with ``donate_argnums=(1, 2)`` so the cache tree is updated
    in place.
    """
    mask = scfg.logit_mask(cfg)

    def decode_chunk(params, caches: Caches, state: SlotState, key):
        B = state.tokens.shape[0]

        def body(carry, _):
            caches, st, key, poisoned = carry
            key, sub = jax.random.split(key)
            logits, caches = decode_step(
                params, st.tokens, caches, st.cur_pos, cfg,
                impl=scfg.attn_impl, policy=policy,
            )
            bad = st.active & ~jnp.isfinite(logits).all(axis=-1)
            active = st.active & ~bad
            nxt = select_token(logits, mask, scfg, sub)
            nxt = jnp.where(active, nxt, st.tokens)
            emitted = active
            remaining = st.remaining - active.astype(jnp.int32)
            done = active & ((nxt == st.eos) | (remaining <= 0))
            st = SlotState(
                tokens=nxt,
                cur_pos=st.cur_pos + active.astype(jnp.int32),
                active=active & ~done,
                remaining=remaining,
                eos=st.eos,
            )
            return (caches, st, key, poisoned | bad), (nxt, emitted)

        poisoned0 = jnp.zeros((B,), bool)
        (caches, state, _, poisoned), (toks, emitted) = jax.lax.scan(
            body, (caches, state, key, poisoned0), None, length=n_steps
        )
        return caches, state, toks, emitted, poisoned

    return decode_chunk


# Process-wide executable LRU: one compile per (arch cfg × serve shape ×
# chunk length) — the AOT "instruction frame package" discipline.  A new
# batcher for the same tenant shape reuses the compiled program instead of
# re-jitting (policy objects are compared by identity and pinned by the
# cached value so their id cannot be recycled while cached).  Bounded so a
# long-running server that churns policies/shapes cannot grow without limit.
_PROGRAM_CACHE: "OrderedDict[Tuple, Tuple[Any, Any]]" = OrderedDict()
_PROGRAM_CACHE_SIZE = 64


def _cached_program(key: Tuple, policy, build):
    hit = _PROGRAM_CACHE.get(key)
    if hit is None:
        _PROGRAM_CACHE[key] = hit = (build(), policy)
        if len(_PROGRAM_CACHE) > _PROGRAM_CACHE_SIZE:
            _PROGRAM_CACHE.popitem(last=False)
    else:
        _PROGRAM_CACHE.move_to_end(key)
    return hit[0]


def decode_chunk_program(cfg, scfg: ServeConfig, n_steps: int, *, policy=None):
    """Jitted :func:`make_decode_chunk` with the cache/state donated."""
    # the traced program never reads scfg.chunk (n_steps is the chunk);
    # normalize it out of the key so batchers that differ only in their max
    # chunk share executables
    key_scfg = dataclasses.replace(scfg, chunk=0)
    return _cached_program(
        ("chunk", cfg, key_scfg, int(n_steps), id(policy)), policy,
        lambda: jax.jit(make_decode_chunk(cfg, scfg, n_steps, policy=policy),
                        donate_argnums=(1, 2)),
    )


def admit_program(cfg, scfg: ServeConfig, *, policy=None):
    """Jitted :func:`make_admit_step` with the cache/state donated."""
    key_scfg = dataclasses.replace(scfg, chunk=0)
    return _cached_program(
        ("admit", cfg, key_scfg, id(policy)), policy,
        lambda: jax.jit(make_admit_step(cfg, scfg, policy=policy),
                        donate_argnums=(2, 3)),
    )


def make_admit_step(cfg, scfg: ServeConfig, *, policy=None):
    """admit_step(params, batch, caches, state, slots, pos0, budget, eos) ->
    (first_tokens (n,), caches, state).

    Right-sized admission: ``batch["tokens"]`` is (n, S) for the *bucketed*
    number of joining requests — prefill runs over n rows, not the full slot
    count — and the fresh caches are merged into the resident tree with
    per-slot scatters (``.at[:, slots].set``) instead of a full-tree
    ``jnp.where``.  Jit with ``donate_argnums=(2, 3)``.

    Duplicate entries in ``slots`` are allowed only when they carry
    identical rows (the batcher pads a partial bucket by repeating row 0),
    making the duplicate-index scatter deterministic.
    """
    mask = scfg.logit_mask(cfg)
    prefill_step = make_prefill_step(cfg, scfg, policy=policy)

    def admit_step(params, batch, caches: Caches, state: SlotState,
                   slots, pos0, budget, eos):
        logits, fresh = prefill_step(params, batch)
        # admission is greedy: the prompt's continuation token
        if mask is not None:
            logits = logits + mask.astype(logits.dtype)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        def merge(old, new):
            return old.at[:, slots].set(new.astype(old.dtype))

        kv = jax.tree.map(merge, caches.kv, fresh.kv)
        ssm = jax.tree.map(merge, caches.ssm, fresh.ssm)
        cross = caches.cross
        if cross is not None and fresh.cross is not None:
            cross = jax.tree.map(merge, cross, fresh.cross)
        # the admission token already counts toward the budget; a slot with
        # nothing left (or an immediate EOS) never activates
        remaining = budget - 1
        state = SlotState(
            tokens=state.tokens.at[slots].set(nxt),
            cur_pos=state.cur_pos.at[slots].set(pos0),
            active=state.active.at[slots].set(
                (remaining > 0) & (nxt != eos)
            ),
            remaining=state.remaining.at[slots].set(remaining),
            eos=state.eos.at[slots].set(eos),
        )
        return nxt, Caches(kv=kv, ssm=ssm, cross=cross), state

    return admit_step


# ---------------------------------------------------------------------------
# Paged KV: on-device page tables, free-list and page-fault allocation
# ---------------------------------------------------------------------------


class PageState(NamedTuple):
    """Device-resident page-pool bookkeeping, donated alongside the caches.

    table:    (B, max_pages) int32 — physical page backing each slot's
              logical page (absolute positions [j*ps, (j+1)*ps)); -1 =
              unmapped.  A physical page is mapped by at most one
              (slot, logical) entry — the no-double-mapping invariant.
    free:     (n_pages + 1,) int32 — stack of free page ids; entries
              [0, free_top) are valid, the last element is scratch for
              masked-out pushes (mirrors the trash page of the pool).
    free_top: () int32 — stack pointer; allocated pages = n_pages - free_top.
    quota:    () int32 — lease cap on allocated pages (the hypervisor's
              ``kv_pages`` dimension); a fault beyond it is denied even if
              the pool has free pages.
    pinned:   (B,) int32 — leading logical pages of each slot's row that are
              owned by the **prefix cache** (shared, read-only): a finishing
              slot never pushes them back onto the free stack — the host's
              refcount ledger decides when a shared page becomes free.
              Decode never writes them either, by construction: the write
              position's logical page is ``cur_pos // page_size >= pinned``.
    """

    table: jax.Array
    free: jax.Array
    free_top: jax.Array
    quota: jax.Array
    pinned: jax.Array

    @property
    def n_pages(self) -> int:
        return self.free.shape[0] - 1


def init_page_state(batch: int, n_pages: int, max_pages: int,
                    *, quota: Optional[int] = None) -> PageState:
    return PageState(
        table=jnp.full((batch, max_pages), -1, jnp.int32),
        free=jnp.concatenate([jnp.arange(n_pages, dtype=jnp.int32),
                              jnp.full((1,), -1, jnp.int32)]),
        free_top=jnp.int32(n_pages),
        quota=jnp.int32(n_pages if quota is None else min(quota, n_pages)),
        pinned=jnp.zeros((batch,), jnp.int32),
    )


def _free_finished_pages(pages_table, free, free_top, finished, pinned):
    """Push every *private* page mapped by a ``finished`` slot back onto the
    free stack (cumsum-ranked scatter; masked-out entries land on the
    scratch element) and clear those table rows.  The slot's first
    ``pinned`` logical pages are cache-owned (shared) and are NOT pushed —
    the host releases their refcounts at sync time.  Returns
    (table, free, free_top, pinned)."""
    scratch = free.shape[0] - 1
    maxp = pages_table.shape[1]
    private = jnp.arange(maxp, dtype=jnp.int32)[None, :] >= pinned[:, None]
    pmask = finished[:, None] & (pages_table >= 0) & private
    flat = pmask.reshape(-1)
    prank = jnp.cumsum(flat.astype(jnp.int32)) - 1
    idx = jnp.where(flat, free_top + prank, scratch)
    free = free.at[idx].set(pages_table.reshape(-1))
    free_top = free_top + flat.sum(dtype=jnp.int32)
    table = jnp.where(finished[:, None], -1, pages_table)
    pinned = jnp.where(finished, 0, pinned)
    return table, free, free_top, pinned


def make_paged_decode_chunk(cfg, scfg: ServeConfig, n_steps: int,
                            page_size: int, *, policy=None):
    """decode_chunk(params, caches, state, pages, key) ->
    (caches, state, pages, tokens (T, B), emitted (T, B), poisoned (B,)).

    The paged twin of :func:`make_decode_chunk`: same ``lax.scan`` with the
    same EOS/budget bookkeeping, plus **page faults handled inside the
    chunk boundary** — a slot whose write position crosses into an
    unmapped logical page pops a page from the device free stack before
    the decode step (so the batcher still pays ≤1 dispatch and ≤1 host
    sync per chunk).  Grants are prefix-ordered by slot index (both the
    stack bound and the quota bound are monotone in the cumsum rank, so a
    denied slot implies every later needer is denied too — pops stay
    contiguous at the top of the stack).  A denied slot (pool dry or
    quota hit) deactivates immediately without emitting — the host sees
    ``active`` drop without EOS/budget and requeues the request.  Pages
    of slots that finish (EOS, budget, denial, or the ``poisoned``
    NaN/inf sentinel — see :func:`make_decode_chunk`) are pushed back
    onto the stack in the same step, so capacity frees mid-chunk.  Jit
    with ``donate_argnums=(1, 2, 3)``.
    """
    mask = scfg.logit_mask(cfg)
    ps = int(page_size)

    def decode_chunk(params, caches: Caches, state: SlotState,
                     pages: PageState, key):
        n_pages = pages.free.shape[0] - 1
        B = state.tokens.shape[0]
        bidx = jnp.arange(B)

        def body(carry, _):
            caches, st, pg, key, poisoned = carry
            key, sub = jax.random.split(key)
            # -- page fault: map the write position's logical page --------
            logical = (st.cur_pos // ps).astype(jnp.int32)
            cur_pid = jnp.take_along_axis(pg.table, logical[:, None], axis=1)[:, 0]
            need = st.active & (cur_pid < 0)
            rank = jnp.cumsum(need.astype(jnp.int32)) - 1
            allocated = n_pages - pg.free_top
            got = need & (rank < pg.free_top) & (allocated + rank < pg.quota)
            pid = pg.free[jnp.clip(pg.free_top - 1 - rank, 0, n_pages)]
            table = pg.table.at[bidx, logical].set(
                jnp.where(got, pid, cur_pid))
            free_top = pg.free_top - got.sum(dtype=jnp.int32)
            oom = need & ~got
            active = st.active & ~oom
            # -- decode against the (updated) page table ------------------
            logits, caches = decode_step(
                params, st.tokens, caches, st.cur_pos, cfg,
                impl=scfg.attn_impl, policy=policy, page_table=table,
            )
            bad = active & ~jnp.isfinite(logits).all(axis=-1)
            active = active & ~bad
            nxt = select_token(logits, mask, scfg, sub)
            nxt = jnp.where(active, nxt, st.tokens)
            emitted = active
            remaining = st.remaining - active.astype(jnp.int32)
            done = active & ((nxt == st.eos) | (remaining <= 0))
            # -- recycle pages of finished slots --------------------------
            table, free, free_top, pinned = _free_finished_pages(
                table, pg.free, free_top, done | oom | bad, pg.pinned)
            st = SlotState(
                tokens=nxt,
                cur_pos=st.cur_pos + active.astype(jnp.int32),
                active=active & ~done,
                remaining=remaining,
                eos=st.eos,
            )
            pg = PageState(table=table, free=free, free_top=free_top,
                           quota=pg.quota, pinned=pinned)
            return (caches, st, pg, key, poisoned | bad), (nxt, emitted)

        poisoned0 = jnp.zeros((B,), bool)
        (caches, state, pages, _, poisoned), (toks, emitted) = jax.lax.scan(
            body, (caches, state, pages, key, poisoned0), None,
            length=n_steps
        )
        return caches, state, pages, toks, emitted, poisoned

    return decode_chunk


def _grant_admission_pages(pages: PageState, ask, np_: int):
    """Prefix-feasible page grants for one admission batch: every asking
    row needs ``np_`` pages.  ``cum`` is monotone, so stack/quota denials
    only ever cut a suffix — pops stay contiguous at the stack top.
    Shared by the cold and cached admit programs (one discipline, edited
    once).  Returns (ok, grant, pid (n, np_), dest, free_top)."""
    n_pages = pages.free.shape[0] - 1
    cum = jnp.cumsum(ask.astype(jnp.int32)) * np_
    allocated = n_pages - pages.free_top
    ok = (cum <= pages.free_top) & (allocated + cum <= pages.quota)
    grant = ask & ok
    ranks = ((jnp.cumsum(grant.astype(jnp.int32)) - 1)[:, None] * np_
             + jnp.arange(np_, dtype=jnp.int32)[None, :])          # (n, np_)
    pid = pages.free[jnp.clip(pages.free_top - 1 - ranks, 0, n_pages)]
    dest = jnp.where(grant[:, None], pid, n_pages)                 # trash
    free_top = pages.free_top - grant.sum(dtype=jnp.int32) * np_
    return ok, grant, pid, dest, free_top


def _scatter_fresh_kv(caches_kv, fresh_kv, dest, *, S: int, np_: int,
                      ps: int, n: int):
    """Scatter freshly-prefilled K/V (per layer: (nb, n, S, Hkv, dh)) into
    the popped pool pages at ``dest`` ((n, np_); trash for denied rows).
    ``fresh_kv`` maps layer key -> (k, v)."""
    pad = np_ * ps - S

    def to_pages(a):
        # (nb, n, S, ...) -> (nb, n * np_, ps, ...)
        if pad:
            width = ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 3)
            a = jnp.pad(a, width)
        return a.reshape(a.shape[0], n * np_, ps, *a.shape[3:])

    def scatter(old, new):
        return old.at[:, dest.reshape(-1)].set(to_pages(new).astype(old.dtype))

    return {
        p: type(view)(k=scatter(view.k, fresh_kv[p][0]),
                      v=scatter(view.v, fresh_kv[p][1]))
        for p, view in caches_kv.items()
    }


def make_paged_admit_step(cfg, scfg: ServeConfig, *, policy=None):
    """admit_step(params, batch, caches, state, pages, slots, pos0, budget,
    eos, real, pin) -> (first_tokens (n,), caches, state, pages, rows).

    Paged admission: right-sized bucketed prefill exactly like
    :func:`make_admit_step`, but the fresh K/V is scattered into
    **freshly-popped pool pages** instead of per-slot dense rows, and the
    joining slots' page-table rows are rewritten.  ``real`` (n,) bool marks
    genuine rows — bucket padding duplicates row 0 and must neither pop
    pages nor write conflicting values (every duplicate scatter carries row
    0's values, keeping the duplicate-index writes deterministic).  A row
    that never activates (immediate EOS / zero budget / allocation denied)
    gets no pages and a cleared table row.  ``pin`` (n,) int32 is the
    prefix-cache pin plan: how many of the row's leading logical pages the
    host will insert into the shared prefix cache after the sync (0 when
    prefix caching is off) — recorded in ``PageState.pinned`` so the chunk
    scan never recycles them.  ``rows`` returns the written page-table rows
    so the host learns the physical ids it is about to share.  Jit with
    ``donate_argnums=(2, 3, 4)``.
    """
    mask = scfg.logit_mask(cfg)

    def admit_step(params, batch, caches: Caches, state: SlotState,
                   pages: PageState, slots, pos0, budget, eos, real, pin):
        ps = None
        for view in caches.kv.values():
            ps = view.k.shape[2]
            break
        assert ps is not None, "paged admission needs at least one attn layer"
        kw: Dict[str, Any] = dict(impl=scfg.attn_impl, policy=policy)
        S = batch["tokens"].shape[1]
        if cfg.family == "vlm":
            kw["extra_embeds"] = batch["extra_embeds"]
            kw["positions"] = batch["positions"]
            S += batch["extra_embeds"].shape[1]
        if cfg.family == "audio":
            kw["enc_out"] = encoder_forward(
                params, batch["frames"], cfg, impl=scfg.attn_impl, policy=policy
            )
        # seed a dense cache sized exactly to the prompt: identity placement,
        # so fresh K/V rows are in absolute-position order for page packing
        logits, fresh = prefill(params, batch["tokens"], cfg, max_len=S, **kw)
        if mask is not None:
            logits = logits + mask.astype(logits.dtype)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        n = nxt.shape[0]
        np_ = pages_for(S, ps)
        maxp = pages.table.shape[1]
        remaining = budget - 1
        wants = (remaining > 0) & (nxt != eos)
        ask = real & wants
        ok, grant, pid, dest, free_top = _grant_admission_pages(
            pages, ask, np_)

        # page-table rows: granted rows map their np_ pages, everything else
        # clears; padding rows carry row 0's values (duplicate-scatter rule)
        row = jnp.full((n, maxp), -1, jnp.int32).at[:, :np_].set(
            jnp.where(grant[:, None], pid, -1))
        row = jnp.where(real[:, None], row, row[0:1])
        table = pages.table.at[slots].set(row)

        kv = _scatter_fresh_kv(
            caches.kv, {p: (fresh.kv[p].k, fresh.kv[p].v) for p in caches.kv},
            dest, S=S, np_=np_, ps=ps, n=n)

        def merge(old, new):
            return old.at[:, slots].set(new.astype(old.dtype))

        ssm = jax.tree.map(merge, caches.ssm, fresh.ssm)
        cross = caches.cross
        if cross is not None and fresh.cross is not None:
            cross = jax.tree.map(merge, cross, fresh.cross)

        activates = wants & (ok | (np_ == 0))
        act_vals = jnp.where(real, activates, activates[0])
        # pin plan only sticks for rows that really mapped their pages;
        # padding rows carry row 0's value (duplicate-scatter rule)
        pin_vals = jnp.where(grant, jnp.clip(pin, 0, np_), 0)
        pin_vals = jnp.where(real, pin_vals, pin_vals[0])
        state = SlotState(
            tokens=state.tokens.at[slots].set(nxt),
            cur_pos=state.cur_pos.at[slots].set(pos0),
            active=state.active.at[slots].set(act_vals),
            remaining=state.remaining.at[slots].set(remaining),
            eos=state.eos.at[slots].set(eos),
        )
        pages = PageState(table=table, free=pages.free, free_top=free_top,
                          quota=pages.quota,
                          pinned=pages.pinned.at[slots].set(pin_vals))
        return nxt, Caches(kv=kv, ssm=ssm, cross=cross), state, pages, row

    return admit_step


def paged_decode_chunk_program(cfg, scfg: ServeConfig, n_steps: int,
                               page_size: int, *, policy=None):
    """Jitted :func:`make_paged_decode_chunk`, caches/state/pages donated."""
    key_scfg = dataclasses.replace(scfg, chunk=0)
    return _cached_program(
        ("paged_chunk", cfg, key_scfg, int(n_steps), int(page_size),
         id(policy)), policy,
        lambda: jax.jit(
            make_paged_decode_chunk(cfg, scfg, n_steps, page_size,
                                    policy=policy),
            donate_argnums=(1, 2, 3)),
    )


def paged_admit_program(cfg, scfg: ServeConfig, *, policy=None):
    """Jitted :func:`make_paged_admit_step`, caches/state/pages donated."""
    key_scfg = dataclasses.replace(scfg, chunk=0)
    return _cached_program(
        ("paged_admit", cfg, key_scfg, id(policy)), policy,
        lambda: jax.jit(make_paged_admit_step(cfg, scfg, policy=policy),
                        donate_argnums=(2, 3, 4)),
    )


def make_cached_admit_step(cfg, scfg: ServeConfig, n_prefix_pages: int,
                           *, policy=None):
    """admit_step(params, batch, caches, state, pages, slots, pos0, budget,
    eos, real, prefix_pids, pin) -> (first_tokens, caches, state, pages,
    rows) — shared-prefix admission.

    The cached twin of :func:`make_paged_admit_step` for rows whose prompt's
    first ``n_prefix_pages`` logical pages are already resident in the
    prefix cache: ``batch["tokens"]`` carries only the **uncached suffix**
    (``prompt_len - n_prefix_pages * page_size`` tokens), the cached pages'
    K/V is gathered from the pool and attended to as a prefix context
    (:func:`repro.models.prefix_prefill`), and only the suffix pages are
    popped from the free stack.  ``prefix_pids`` (n, n_prefix_pages) are the
    cached physical page ids, mapped **read-only** into the joining slot's
    table row — the copy-on-write discipline: the divergent tail (at
    minimum the page holding the last prompt token — the prefix is capped
    at ``(prompt_len - 1) // page_size`` pages, so a *fully* cached prompt
    still prefills its last page privately) always writes private pages,
    shared pages are never written.  ``pin`` (n,) counts the row's leading
    cache-owned logical pages (hits + the host's planned inserts), recorded
    in ``PageState.pinned``.  Bucketing/padding rules are identical to the
    cold program.  Jit with ``donate_argnums=(2, 3, 4)``.
    """
    mask = scfg.logit_mask(cfg)
    kp = int(n_prefix_pages)
    assert kp >= 1, "use the cold paged admit program for zero cached pages"

    def admit_step(params, batch, caches: Caches, state: SlotState,
                   pages: PageState, slots, pos0, budget, eos, real,
                   prefix_pids, pin):
        ps = None
        for view in caches.kv.values():
            ps = view.k.shape[2]
            break
        assert ps is not None, "cached admission needs at least one attn layer"
        Lp = kp * ps
        n, S = batch["tokens"].shape                       # S = suffix length

        # cached prefix context: pool pages -> (nb, n, Lp, Hkv, dh) per layer
        def gather(a):
            g = a[:, prefix_pids]                          # (nb,n,kp,ps,H,dh)
            return g.reshape(g.shape[0], n, Lp, *g.shape[4:])

        prefix_kv = {p: (gather(view.k), gather(view.v))
                     for p, view in caches.kv.items()}
        logits, ys = prefix_prefill(
            params, batch["tokens"], prefix_kv, cfg, prefix_len=Lp,
            impl=scfg.attn_impl, policy=policy,
        )
        if mask is not None:
            logits = logits + mask.astype(logits.dtype)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        np_ = pages_for(S, ps)                             # private pages
        maxp = pages.table.shape[1]
        remaining = budget - 1
        wants = (remaining > 0) & (nxt != eos)
        ask = real & wants
        ok, grant, pid, dest, free_top = _grant_admission_pages(
            pages, ask, np_)

        # table row: [shared prefix (read-only) | fresh suffix | -1 ...]
        row = jnp.full((n, maxp), -1, jnp.int32)
        row = row.at[:, :kp].set(jnp.where(grant[:, None], prefix_pids, -1))
        row = row.at[:, kp:kp + np_].set(jnp.where(grant[:, None], pid, -1))
        row = jnp.where(real[:, None], row, row[0:1])
        table = pages.table.at[slots].set(row)

        kv = _scatter_fresh_kv(caches.kv, ys, dest, S=S, np_=np_, ps=ps, n=n)

        activates = wants & ok
        act_vals = jnp.where(real, activates, activates[0])
        pin_vals = jnp.where(grant, jnp.clip(pin, kp, kp + np_), 0)
        pin_vals = jnp.where(real, pin_vals, pin_vals[0])
        state = SlotState(
            tokens=state.tokens.at[slots].set(nxt),
            cur_pos=state.cur_pos.at[slots].set(pos0),
            active=state.active.at[slots].set(act_vals),
            remaining=state.remaining.at[slots].set(remaining),
            eos=state.eos.at[slots].set(eos),
        )
        pages = PageState(table=table, free=pages.free, free_top=free_top,
                          quota=pages.quota,
                          pinned=pages.pinned.at[slots].set(pin_vals))
        return (nxt, Caches(kv=kv, ssm=caches.ssm, cross=caches.cross),
                state, pages, row)

    return admit_step


def cached_admit_program(cfg, scfg: ServeConfig, n_prefix_pages: int,
                         *, policy=None):
    """Jitted :func:`make_cached_admit_step`, caches/state/pages donated.
    One executable per (arch × serve shape × prefix-page count) — the
    prefix-page counts are bounded by ``prompt_len / page_size``, so the
    program cache stays small."""
    key_scfg = dataclasses.replace(scfg, chunk=0)
    return _cached_program(
        ("cached_admit", cfg, key_scfg, int(n_prefix_pages), id(policy)),
        policy,
        lambda: jax.jit(
            make_cached_admit_step(cfg, scfg, n_prefix_pages, policy=policy),
            donate_argnums=(2, 3, 4)),
    )


def make_page_push():
    """push(pages, pids (K,)) -> pages — return evicted prefix-cache pages
    (host decision: refcount hit 0 and the LRU chose them) to the device
    free stack.  ``pids`` entries < 0 are padding.  Jit with
    ``donate_argnums=(0,)``."""

    def push(pages: PageState, pids):
        scratch = pages.free.shape[0] - 1
        valid = pids >= 0
        rank = jnp.cumsum(valid.astype(jnp.int32)) - 1
        idx = jnp.where(valid, pages.free_top + rank, scratch)
        free = pages.free.at[idx].set(pids)
        return pages._replace(
            free=free, free_top=pages.free_top + valid.sum(dtype=jnp.int32))

    return push


def page_push_program():
    """Jitted :func:`make_page_push` (page state donated); one cached
    executable, re-traced per pid-vector shape by jit itself."""
    return _cached_program(
        ("page_push",), None,
        lambda: jax.jit(make_page_push(), donate_argnums=(0,)),
    )


# ---------------------------------------------------------------------------
# Host generate loop (chunked)
# ---------------------------------------------------------------------------


def generate(
    params, cfg, prompt_tokens, *, n_new: int, scfg: Optional[ServeConfig] = None,
    policy=None, extras: Optional[Dict[str, Any]] = None, seed: int = 0,
):
    """Prefill the prompt, then decode ``n_new`` tokens through the chunked
    path: the remaining budget is covered by power-of-two chunk buckets
    (at most ceil((n_new-1)/chunk) + log2(chunk) dispatches instead of
    n_new-1 — the bucketing bounds the jit cache).

    prompt_tokens: (B, S) int32.  Returns (B, n_new) int32.
    """
    B, S = prompt_tokens.shape
    scfg = scfg or ServeConfig(max_len=S + n_new)
    batch = {"tokens": prompt_tokens, **(extras or {})}
    prefill_step = jax.jit(make_prefill_step(cfg, scfg, policy=policy))
    logits, caches = prefill_step(params, batch)
    mask = scfg.logit_mask(cfg)
    if mask is not None:
        logits = logits + mask.astype(logits.dtype)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    offset = S
    if cfg.family == "vlm" and extras and "extra_embeds" in extras:
        offset = S + extras["extra_embeds"].shape[1]

    out = [tok[:, None]]
    left = n_new - 1
    state = SlotState(
        tokens=tok,
        cur_pos=jnp.full((B,), offset, jnp.int32),
        active=jnp.ones((B,), bool),
        remaining=jnp.full((B,), max(left, 0), jnp.int32),
        eos=jnp.full((B,), -1, jnp.int32),
    )
    key = jax.random.PRNGKey(seed)
    while left > 0:
        T = chunk_bucket(min(left, max(scfg.chunk, 1)))
        fn = decode_chunk_program(cfg, scfg, T, policy=policy)
        key, sub = jax.random.split(key)
        caches, state, toks, _, _ = fn(params, caches, state, sub)
        out.append(jnp.moveaxis(toks, 0, 1))
        left -= T
    return jnp.concatenate(out, axis=1)
