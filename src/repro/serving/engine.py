"""Inference engine: prefill/serve/decode-chunk factories and host generate.

``prefill_step`` and ``serve_step`` are the two programs the dry-run lowers
for the inference cells (prefill_32k → prefill_step; decode_32k / long_500k
→ serve_step).  Both are pure functions of (params, inputs, caches) so the
tenancy layer can AOT-compile them per (arch × shape × lease size) — the
TPU-side "instruction frame package".

The serving hot path is **chunked and donated**:

* :func:`make_decode_chunk` fuses ``n_steps`` decode iterations into one
  ``lax.scan`` program with on-device slot bookkeeping (:class:`SlotState`:
  active mask, per-slot positions, EOS/max-token detection inside the scan),
  so a batcher issues one device dispatch and one host sync per chunk
  instead of per token.
* Callers jit these programs with ``donate_argnums`` on the cache/state
  arguments so XLA updates the ring-buffer KV in place; without donation
  every token would copy the entire cache tree (the dominant decode-bytes
  term).  A donated input buffer is dead after the call — owners must adopt
  the returned tree (see ``ContinuousBatcher``).
* :func:`make_admit_step` fuses prefill + per-slot scatter admission into
  one donated program (see ``serving.batcher`` for the slot protocol).
* The vocab-padding mask is built **once** per (vocab, padded) pair
  (:meth:`ServeConfig.logit_mask`) and applied as a fused additive mask,
  instead of rebuilding a full-logits ``.at[..., vocab:].set(-inf)`` copy on
  every step.

Invariant: a slot that deactivates mid-chunk (EOS or token budget) keeps
decoding with its position frozen — it overwrites its *own* ring slot with
dead values, which is safe because admission re-seeds the slot's cache from
prefill before it is reused.
"""

from __future__ import annotations

import dataclasses
import functools
from collections import OrderedDict
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, encoder_forward, prefill
from repro.models.transformer import Caches


@functools.lru_cache(maxsize=32)
def _logit_mask(vocab: int, vocab_padded: int):
    """Additive mask (Vp,) — 0 on the real vocab, -inf on padding.  Built
    once and closed over by the step functions (a hoisted jit constant),
    replacing the per-step full-logits ``.set(-inf)`` copy."""
    if vocab_padded <= vocab:
        return None
    m = np.zeros((vocab_padded,), np.float32)
    m[vocab:] = -np.inf
    return jnp.asarray(m)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int
    attn_impl: str = "xla"       # xla | pallas
    greedy: bool = True
    temperature: float = 1.0
    chunk: int = 8               # max decode steps fused per device dispatch

    def logit_mask(self, cfg):
        return _logit_mask(cfg.vocab, cfg.vocab_padded)


def chunk_bucket(n: int) -> int:
    """Largest power of two ≤ n — the fixed set of chunk/prefill shapes the
    jit cache may hold (log2 many programs, no per-request recompiles)."""
    return 1 << (max(n, 1).bit_length() - 1)


def select_token(logits, mask, scfg: ServeConfig, key):
    """Greedy or sampled next-token selection under the vocab-padding mask."""
    if mask is not None:
        logits = logits + mask.astype(logits.dtype)
    if scfg.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / scfg.temperature, axis=-1
    ).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Single-step programs (AOT surface for cells.py / tenancy)
# ---------------------------------------------------------------------------


def make_prefill_step(cfg, scfg: ServeConfig, *, policy=None):
    """prefill_step(params, batch) -> (last-token logits, Caches).

    batch: {"tokens": (B, S)} + family extras (extra_embeds/positions/frames).
    """

    def prefill_step(params, batch):
        kw: Dict[str, Any] = dict(impl=scfg.attn_impl, policy=policy)
        if cfg.family == "vlm":
            kw["extra_embeds"] = batch["extra_embeds"]
            kw["positions"] = batch["positions"]
        if cfg.family == "audio":
            kw["enc_out"] = encoder_forward(
                params, batch["frames"], cfg, impl=scfg.attn_impl, policy=policy
            )
        return prefill(params, batch["tokens"], cfg, max_len=scfg.max_len, **kw)

    return prefill_step


def make_serve_step(cfg, scfg: ServeConfig, *, policy=None):
    """serve_step(params, tokens (B,), caches, cur_pos (B,), key) ->
    (next_tokens (B,), logits, caches)."""
    mask = scfg.logit_mask(cfg)

    def serve_step(params, tokens, caches: Caches, cur_pos, key):
        logits, caches = decode_step(
            params, tokens, caches, cur_pos, cfg, impl=scfg.attn_impl,
            policy=policy,
        )
        if mask is not None:
            logits = logits + mask.astype(logits.dtype)
        nxt = select_token(logits, None, scfg, key)
        return nxt, logits, caches

    return serve_step


# ---------------------------------------------------------------------------
# Chunked decode with on-device slot bookkeeping
# ---------------------------------------------------------------------------


class SlotState(NamedTuple):
    """Per-slot decode bookkeeping, resident on device between dispatches.

    tokens:     (B,) int32 — last emitted token (next decode input)
    cur_pos:    (B,) int32 — absolute position the next token writes to
    active:     (B,) bool  — slot is mid-generation
    remaining:  (B,) int32 — decode tokens left until the slot's max budget
    eos:        (B,) int32 — per-slot EOS id, -1 = none
    """

    tokens: jax.Array
    cur_pos: jax.Array
    active: jax.Array
    remaining: jax.Array
    eos: jax.Array


def init_slot_state(batch: int) -> SlotState:
    return SlotState(
        tokens=jnp.zeros((batch,), jnp.int32),
        cur_pos=jnp.zeros((batch,), jnp.int32),
        active=jnp.zeros((batch,), bool),
        remaining=jnp.zeros((batch,), jnp.int32),
        eos=jnp.full((batch,), -1, jnp.int32),
    )


def make_decode_chunk(cfg, scfg: ServeConfig, n_steps: int, *, policy=None):
    """decode_chunk(params, caches, state, key) ->
    (caches, state, tokens (T, B), emitted (T, B)).

    One ``lax.scan`` over ``n_steps`` decode iterations.  EOS and
    token-budget detection happen inside the scan: a slot that finishes
    deactivates immediately, its position freezes, and later iterations
    emit nothing for it (``emitted`` is the validity mask).  Jit this with
    ``donate_argnums=(1, 2)`` so the cache tree is updated in place.
    """
    mask = scfg.logit_mask(cfg)

    def decode_chunk(params, caches: Caches, state: SlotState, key):
        def body(carry, _):
            caches, st, key = carry
            key, sub = jax.random.split(key)
            logits, caches = decode_step(
                params, st.tokens, caches, st.cur_pos, cfg,
                impl=scfg.attn_impl, policy=policy,
            )
            nxt = select_token(logits, mask, scfg, sub)
            nxt = jnp.where(st.active, nxt, st.tokens)
            emitted = st.active
            remaining = st.remaining - st.active.astype(jnp.int32)
            done = st.active & ((nxt == st.eos) | (remaining <= 0))
            st = SlotState(
                tokens=nxt,
                cur_pos=st.cur_pos + st.active.astype(jnp.int32),
                active=st.active & ~done,
                remaining=remaining,
                eos=st.eos,
            )
            return (caches, st, key), (nxt, emitted)

        (caches, state, _), (toks, emitted) = jax.lax.scan(
            body, (caches, state, key), None, length=n_steps
        )
        return caches, state, toks, emitted

    return decode_chunk


# Process-wide executable LRU: one compile per (arch cfg × serve shape ×
# chunk length) — the AOT "instruction frame package" discipline.  A new
# batcher for the same tenant shape reuses the compiled program instead of
# re-jitting (policy objects are compared by identity and pinned by the
# cached value so their id cannot be recycled while cached).  Bounded so a
# long-running server that churns policies/shapes cannot grow without limit.
_PROGRAM_CACHE: "OrderedDict[Tuple, Tuple[Any, Any]]" = OrderedDict()
_PROGRAM_CACHE_SIZE = 64


def _cached_program(key: Tuple, policy, build):
    hit = _PROGRAM_CACHE.get(key)
    if hit is None:
        _PROGRAM_CACHE[key] = hit = (build(), policy)
        if len(_PROGRAM_CACHE) > _PROGRAM_CACHE_SIZE:
            _PROGRAM_CACHE.popitem(last=False)
    else:
        _PROGRAM_CACHE.move_to_end(key)
    return hit[0]


def decode_chunk_program(cfg, scfg: ServeConfig, n_steps: int, *, policy=None):
    """Jitted :func:`make_decode_chunk` with the cache/state donated."""
    # the traced program never reads scfg.chunk (n_steps is the chunk);
    # normalize it out of the key so batchers that differ only in their max
    # chunk share executables
    key_scfg = dataclasses.replace(scfg, chunk=0)
    return _cached_program(
        ("chunk", cfg, key_scfg, int(n_steps), id(policy)), policy,
        lambda: jax.jit(make_decode_chunk(cfg, scfg, n_steps, policy=policy),
                        donate_argnums=(1, 2)),
    )


def admit_program(cfg, scfg: ServeConfig, *, policy=None):
    """Jitted :func:`make_admit_step` with the cache/state donated."""
    key_scfg = dataclasses.replace(scfg, chunk=0)
    return _cached_program(
        ("admit", cfg, key_scfg, id(policy)), policy,
        lambda: jax.jit(make_admit_step(cfg, scfg, policy=policy),
                        donate_argnums=(2, 3)),
    )


def make_admit_step(cfg, scfg: ServeConfig, *, policy=None):
    """admit_step(params, batch, caches, state, slots, pos0, budget, eos) ->
    (first_tokens (n,), caches, state).

    Right-sized admission: ``batch["tokens"]`` is (n, S) for the *bucketed*
    number of joining requests — prefill runs over n rows, not the full slot
    count — and the fresh caches are merged into the resident tree with
    per-slot scatters (``.at[:, slots].set``) instead of a full-tree
    ``jnp.where``.  Jit with ``donate_argnums=(2, 3)``.

    Duplicate entries in ``slots`` are allowed only when they carry
    identical rows (the batcher pads a partial bucket by repeating row 0),
    making the duplicate-index scatter deterministic.
    """
    mask = scfg.logit_mask(cfg)
    prefill_step = make_prefill_step(cfg, scfg, policy=policy)

    def admit_step(params, batch, caches: Caches, state: SlotState,
                   slots, pos0, budget, eos):
        logits, fresh = prefill_step(params, batch)
        # admission is greedy: the prompt's continuation token
        if mask is not None:
            logits = logits + mask.astype(logits.dtype)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        def merge(old, new):
            return old.at[:, slots].set(new.astype(old.dtype))

        kv = jax.tree.map(merge, caches.kv, fresh.kv)
        ssm = jax.tree.map(merge, caches.ssm, fresh.ssm)
        cross = caches.cross
        if cross is not None and fresh.cross is not None:
            cross = jax.tree.map(merge, cross, fresh.cross)
        # the admission token already counts toward the budget; a slot with
        # nothing left (or an immediate EOS) never activates
        remaining = budget - 1
        state = SlotState(
            tokens=state.tokens.at[slots].set(nxt),
            cur_pos=state.cur_pos.at[slots].set(pos0),
            active=state.active.at[slots].set(
                (remaining > 0) & (nxt != eos)
            ),
            remaining=state.remaining.at[slots].set(remaining),
            eos=state.eos.at[slots].set(eos),
        )
        return nxt, Caches(kv=kv, ssm=ssm, cross=cross), state

    return admit_step


# ---------------------------------------------------------------------------
# Host generate loop (chunked)
# ---------------------------------------------------------------------------


def generate(
    params, cfg, prompt_tokens, *, n_new: int, scfg: Optional[ServeConfig] = None,
    policy=None, extras: Optional[Dict[str, Any]] = None, seed: int = 0,
):
    """Prefill the prompt, then decode ``n_new`` tokens through the chunked
    path: the remaining budget is covered by power-of-two chunk buckets
    (at most ceil((n_new-1)/chunk) + log2(chunk) dispatches instead of
    n_new-1 — the bucketing bounds the jit cache).

    prompt_tokens: (B, S) int32.  Returns (B, n_new) int32.
    """
    B, S = prompt_tokens.shape
    scfg = scfg or ServeConfig(max_len=S + n_new)
    batch = {"tokens": prompt_tokens, **(extras or {})}
    prefill_step = jax.jit(make_prefill_step(cfg, scfg, policy=policy))
    logits, caches = prefill_step(params, batch)
    mask = scfg.logit_mask(cfg)
    if mask is not None:
        logits = logits + mask.astype(logits.dtype)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    offset = S
    if cfg.family == "vlm" and extras and "extra_embeds" in extras:
        offset = S + extras["extra_embeds"].shape[1]

    out = [tok[:, None]]
    left = n_new - 1
    state = SlotState(
        tokens=tok,
        cur_pos=jnp.full((B,), offset, jnp.int32),
        active=jnp.ones((B,), bool),
        remaining=jnp.full((B,), max(left, 0), jnp.int32),
        eos=jnp.full((B,), -1, jnp.int32),
    )
    key = jax.random.PRNGKey(seed)
    while left > 0:
        T = chunk_bucket(min(left, max(scfg.chunk, 1)))
        fn = decode_chunk_program(cfg, scfg, T, policy=policy)
        key, sub = jax.random.split(key)
        caches, state, toks, _ = fn(params, caches, state, sub)
        out.append(jnp.moveaxis(toks, 0, 1))
        left -= T
    return jnp.concatenate(out, axis=1)
