"""Inference engine: prefill/serve/decode-chunk factories and host generate.

``prefill_step`` and ``serve_step`` are the two programs the dry-run lowers
for the inference cells (prefill_32k → prefill_step; decode_32k / long_500k
→ serve_step).  Both are pure functions of (params, inputs, caches) so the
tenancy layer can AOT-compile them per (arch × shape × lease size) — the
TPU-side "instruction frame package".

The serving hot path is **chunked and donated**:

* :func:`make_decode_chunk` fuses ``n_steps`` decode iterations into one
  ``lax.scan`` program with on-device slot bookkeeping (:class:`SlotState`:
  active mask, per-slot positions, EOS/max-token detection inside the scan),
  so a batcher issues one device dispatch and one host sync per chunk
  instead of per token.
* Callers jit these programs with ``donate_argnums`` on the cache/state
  arguments so XLA updates the ring-buffer KV in place; without donation
  every token would copy the entire cache tree (the dominant decode-bytes
  term).  A donated input buffer is dead after the call — owners must adopt
  the returned tree (see ``ContinuousBatcher``).
* :func:`make_admit_step` fuses prefill + per-slot scatter admission into
  one donated program (see ``serving.batcher`` for the slot protocol).
* The vocab-padding mask is built **once** per (vocab, padded) pair
  (:meth:`ServeConfig.logit_mask`) and applied as a fused additive mask,
  instead of rebuilding a full-logits ``.at[..., vocab:].set(-inf)`` copy on
  every step.

Invariant: a slot that deactivates mid-chunk (EOS or token budget) keeps
decoding with its position frozen — it overwrites its *own* ring slot with
dead values, which is safe because admission re-seeds the slot's cache from
prefill before it is reused.
"""

from __future__ import annotations

import dataclasses
import functools
from collections import OrderedDict
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (
    decode_step, encoder_forward, prefill, prefix_prefill, verify_step,
)
from repro.models.attention import check_attn_impl
from repro.models.transformer import Caches

from .kv_cache import pages_for


@functools.lru_cache(maxsize=32)
def _logit_mask(vocab: int, vocab_padded: int):
    """Additive mask (Vp,) — 0 on the real vocab, -inf on padding.  Built
    once and closed over by the step functions (a hoisted jit constant),
    replacing the per-step full-logits ``.set(-inf)`` copy."""
    if vocab_padded <= vocab:
        return None
    m = np.zeros((vocab_padded,), np.float32)
    m[vocab:] = -np.inf
    return jnp.asarray(m)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int
    attn_impl: str = "xla"       # see models.attention.ATTN_CAPABILITIES
    greedy: bool = True
    temperature: float = 1.0
    chunk: int = 8               # max decode steps fused per device dispatch

    def __post_init__(self):
        # fail at config construction, not three layers into a jit trace;
        # mode-specific checks (paged/prefix/sliding_window) happen where
        # the mode is known — ContinuousBatcher.__init__
        check_attn_impl(self.attn_impl, "dense")

    def logit_mask(self, cfg):
        return _logit_mask(cfg.vocab, cfg.vocab_padded)


def chunk_bucket(n: int) -> int:
    """Largest power of two ≤ n — the fixed set of chunk/prefill shapes the
    jit cache may hold (log2 many programs, no per-request recompiles)."""
    return 1 << (max(n, 1).bit_length() - 1)


def select_token(logits, mask, scfg: ServeConfig, key):
    """Greedy or sampled next-token selection under the vocab-padding mask."""
    if mask is not None:
        logits = logits + mask.astype(logits.dtype)
    if scfg.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / scfg.temperature, axis=-1
    ).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Single-step programs (AOT surface for cells.py / tenancy)
# ---------------------------------------------------------------------------


def make_prefill_step(cfg, scfg: ServeConfig, *, policy=None):
    """prefill_step(params, batch) -> (last-token logits, Caches).

    batch: {"tokens": (B, S)} + family extras (extra_embeds/positions/frames).
    """

    def prefill_step(params, batch):
        kw: Dict[str, Any] = dict(impl=scfg.attn_impl, policy=policy)
        if cfg.family == "vlm":
            kw["extra_embeds"] = batch["extra_embeds"]
            kw["positions"] = batch["positions"]
        if cfg.family == "audio":
            kw["enc_out"] = encoder_forward(
                params, batch["frames"], cfg, impl=scfg.attn_impl, policy=policy
            )
        return prefill(params, batch["tokens"], cfg, max_len=scfg.max_len, **kw)

    return prefill_step


def make_serve_step(cfg, scfg: ServeConfig, *, policy=None):
    """serve_step(params, tokens (B,), caches, cur_pos (B,), key) ->
    (next_tokens (B,), logits, caches)."""
    mask = scfg.logit_mask(cfg)

    def serve_step(params, tokens, caches: Caches, cur_pos, key):
        logits, caches = decode_step(
            params, tokens, caches, cur_pos, cfg, impl=scfg.attn_impl,
            policy=policy,
        )
        if mask is not None:
            logits = logits + mask.astype(logits.dtype)
        nxt = select_token(logits, None, scfg, key)
        return nxt, logits, caches

    return serve_step


# ---------------------------------------------------------------------------
# Chunked decode with on-device slot bookkeeping
# ---------------------------------------------------------------------------


class SlotState(NamedTuple):
    """Per-slot decode bookkeeping, resident on device between dispatches.

    tokens:     (B,) int32 — last emitted token (next decode input)
    cur_pos:    (B,) int32 — absolute position the next token writes to
    active:     (B,) bool  — slot is mid-generation
    remaining:  (B,) int32 — decode tokens left until the slot's max budget
    eos:        (B,) int32 — per-slot EOS id, -1 = none
    """

    tokens: jax.Array
    cur_pos: jax.Array
    active: jax.Array
    remaining: jax.Array
    eos: jax.Array


def init_slot_state(batch: int) -> SlotState:
    return SlotState(
        tokens=jnp.zeros((batch,), jnp.int32),
        cur_pos=jnp.zeros((batch,), jnp.int32),
        active=jnp.zeros((batch,), bool),
        remaining=jnp.zeros((batch,), jnp.int32),
        eos=jnp.full((batch,), -1, jnp.int32),
    )


def make_decode_chunk(cfg, scfg: ServeConfig, n_steps: int, *, policy=None):
    """decode_chunk(params, caches, state, key) ->
    (caches, state, tokens (T, B), emitted (T, B), poisoned (B,)).

    One ``lax.scan`` over ``n_steps`` decode iterations.  EOS and
    token-budget detection happen inside the scan: a slot that finishes
    deactivates immediately, its position freezes, and later iterations
    emit nothing for it (``emitted`` is the validity mask).

    ``poisoned`` is the fault sentinel: a slot whose logits come back
    non-finite (NaN/inf — a corrupted cache page, a bad reduction) is
    deactivated *before* its token is selected or emitted, so a poisoned
    value never enters any output stream — the blast radius is the slot.
    The host requeues the flagged request (see ``ContinuousBatcher``).
    Jit this with ``donate_argnums=(1, 2)`` so the cache tree is updated
    in place.
    """
    mask = scfg.logit_mask(cfg)

    def decode_chunk(params, caches: Caches, state: SlotState, key):
        B = state.tokens.shape[0]

        def body(carry, _):
            caches, st, key, poisoned = carry
            key, sub = jax.random.split(key)
            logits, caches = decode_step(
                params, st.tokens, caches, st.cur_pos, cfg,
                impl=scfg.attn_impl, policy=policy,
            )
            bad = st.active & ~jnp.isfinite(logits).all(axis=-1)
            active = st.active & ~bad
            nxt = select_token(logits, mask, scfg, sub)
            nxt = jnp.where(active, nxt, st.tokens)
            emitted = active
            remaining = st.remaining - active.astype(jnp.int32)
            done = active & ((nxt == st.eos) | (remaining <= 0))
            st = SlotState(
                tokens=nxt,
                cur_pos=st.cur_pos + active.astype(jnp.int32),
                active=active & ~done,
                remaining=remaining,
                eos=st.eos,
            )
            return (caches, st, key, poisoned | bad), (nxt, emitted)

        poisoned0 = jnp.zeros((B,), bool)
        (caches, state, _, poisoned), (toks, emitted) = jax.lax.scan(
            body, (caches, state, key, poisoned0), None, length=n_steps
        )
        return caches, state, toks, emitted, poisoned

    return decode_chunk


class ProgramRegistry:
    """Process-wide executable LRU: one compile per (program kind × arch cfg
    × serve shape × trace-relevant shape ints) — the AOT "instruction frame
    package" discipline of the paper's static compilation stage.

    Every serving program (decode chunks, admits, speculative variants, the
    page-push helper) registers through :meth:`get` with the **same key
    scheme**: ``(kind, cfg, scfg-with-chunk-normalized, shapes, id(policy))``
    — no per-program hand-rolled key tuples.  ``scfg.chunk`` is normalized
    out because the traced program never reads it (the chunk length rides in
    ``shapes``), so batchers that differ only in their max chunk share
    executables.  Policy objects are compared by identity and pinned by the
    cached value so their id cannot be recycled while cached.  Bounded LRU:
    a long-running server that churns policies/shapes cannot grow without
    limit.

    A new batcher for the same tenant shape reuses the compiled program
    instead of re-jitting; :data:`PROGRAMS` is the module singleton every
    ``*_program`` wrapper routes through.

    Tensor-sharded programs additionally key on the **mesh fingerprint**
    (axis names × shape × concrete device ids): two tenants whose leases
    differ in TP width *or* device set must never collide — same-shape
    programs over different devices are different executables.  Per-key
    ``hits`` counters expose registry effectiveness (a re-meshed batcher
    re-keying onto an existing mesh should hit, never rebuild).
    """

    def __init__(self, maxsize: int = 64):
        self.maxsize = int(maxsize)
        self._cache: "OrderedDict[Tuple, Tuple[Any, Any]]" = OrderedDict()
        self.hits: Dict[Tuple, int] = {}

    @staticmethod
    def mesh_key(mesh) -> Optional[Tuple]:
        """Hashable fingerprint of a mesh (None passes through)."""
        if mesh is None:
            return None
        return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
                tuple(int(d.id) for d in mesh.devices.flat))

    @staticmethod
    def make_key(kind: str, cfg, scfg: Optional[ServeConfig],
                 shapes: Tuple, policy, mesh=None) -> Tuple:
        key_scfg = (None if scfg is None
                    else dataclasses.replace(scfg, chunk=0))
        return (kind, cfg, key_scfg, tuple(shapes), id(policy),
                ProgramRegistry.mesh_key(mesh))

    def get(self, kind: str, cfg, scfg: Optional[ServeConfig],
            shapes: Tuple, policy, build, *, mesh=None):
        """Return the cached executable for the key, building (and pinning
        ``policy``) on miss."""
        return self.get_raw(
            self.make_key(kind, cfg, scfg, shapes, policy, mesh),
            policy, build)

    def get_raw(self, key: Tuple, policy, build):
        hit = self._cache.get(key)
        if hit is None:
            self._cache[key] = hit = (build(), policy)
            self.hits.setdefault(key, 0)
            if len(self._cache) > self.maxsize:
                evicted, _ = self._cache.popitem(last=False)
                self.hits.pop(evicted, None)
        else:
            self.hits[key] += 1
            self._cache.move_to_end(key)
        return hit[0]

    def __len__(self) -> int:
        return len(self._cache)

    def __contains__(self, key: Tuple) -> bool:
        return key in self._cache

    def clear(self) -> None:
        self._cache.clear()
        self.hits.clear()


PROGRAMS = ProgramRegistry()


def _tp_program(kind: str, cfg, scfg, shapes: Tuple, policy, mesh,
                build_local, *, paged: bool, n_in: int, cache_in: int,
                n_out: int, cache_out: int, donate: Tuple[int, ...]):
    """Register + build one tensor-sharded serving program.

    ``build_local(local_cfg)`` returns the un-jitted program traced at the
    shard-local model (heads/d_ff divided by tp) — the *same* make_* the
    single-device path uses.  It is wrapped in a fully-manual shard_map over
    the tenant's flat ("tp",) mesh: params follow ``tp_param_specs``, the
    KV tree ``tp_cache_specs`` (head axis split), and every other argument
    and output — slot state, page tables, draft state, token batches, PRNG
    keys — is replicated (identical on every shard: replicated inputs plus
    the policy's per-layer psums keep all non-head-sharded values
    bit-identical, which is what makes the replicated out_specs sound under
    check_rep=False).  One jit, same donation pattern as the single-device
    twin, so the ≤1 dispatch / ≤1 sync per chunk contract is unchanged.
    """
    from jax.sharding import PartitionSpec
    from repro.distributed.sharding import (
        shard_map_compat, tp_cache_specs, tp_local_cfg, tp_param_specs)

    lcfg = tp_local_cfg(cfg, int(mesh.shape["tp"]))

    def build():
        cspec = tp_cache_specs(cfg, paged=paged)
        in_specs = [PartitionSpec()] * n_in
        in_specs[0] = tp_param_specs(cfg)
        in_specs[cache_in] = cspec
        out_specs = [PartitionSpec()] * n_out
        out_specs[cache_out] = cspec
        fn = shard_map_compat(
            build_local(lcfg), mesh,
            in_specs=tuple(in_specs), out_specs=tuple(out_specs),
            manual_axes={"tp"},
        )
        return jax.jit(fn, donate_argnums=donate)

    return PROGRAMS.get(kind, cfg, scfg, shapes, policy, build, mesh=mesh)


def decode_chunk_program(cfg, scfg: ServeConfig, n_steps: int, *, policy=None,
                         mesh=None):
    """Jitted :func:`make_decode_chunk` with the cache/state donated.  With
    ``mesh`` (a flat ("tp",) mesh) the chunk runs tensor-sharded and
    ``policy`` must be the batcher's ``TPShardPolicy``."""
    if mesh is not None:
        return _tp_program(
            "chunk", cfg, scfg, (int(n_steps),), policy, mesh,
            lambda lcfg: make_decode_chunk(lcfg, scfg, n_steps,
                                           policy=policy),
            paged=False, n_in=4, cache_in=1, n_out=5, cache_out=0,
            donate=(1, 2))
    return PROGRAMS.get(
        "chunk", cfg, scfg, (int(n_steps),), policy,
        lambda: jax.jit(make_decode_chunk(cfg, scfg, n_steps, policy=policy),
                        donate_argnums=(1, 2)),
    )


def admit_program(cfg, scfg: ServeConfig, *, policy=None, mesh=None):
    """Jitted :func:`make_admit_step` with the cache/state donated."""
    if mesh is not None:
        return _tp_program(
            "admit", cfg, scfg, (), policy, mesh,
            lambda lcfg: make_admit_step(lcfg, scfg, policy=policy),
            paged=False, n_in=8, cache_in=2, n_out=3, cache_out=1,
            donate=(2, 3))
    return PROGRAMS.get(
        "admit", cfg, scfg, (), policy,
        lambda: jax.jit(make_admit_step(cfg, scfg, policy=policy),
                        donate_argnums=(2, 3)),
    )


def make_admit_step(cfg, scfg: ServeConfig, *, policy=None):
    """admit_step(params, batch, caches, state, slots, pos0, budget, eos) ->
    (first_tokens (n,), caches, state).

    Right-sized admission: ``batch["tokens"]`` is (n, S) for the *bucketed*
    number of joining requests — prefill runs over n rows, not the full slot
    count — and the fresh caches are merged into the resident tree with
    per-slot scatters (``.at[:, slots].set``) instead of a full-tree
    ``jnp.where``.  Jit with ``donate_argnums=(2, 3)``.

    Duplicate entries in ``slots`` are allowed only when they carry
    identical rows (the batcher pads a partial bucket by repeating row 0),
    making the duplicate-index scatter deterministic.
    """
    mask = scfg.logit_mask(cfg)
    prefill_step = make_prefill_step(cfg, scfg, policy=policy)

    def admit_step(params, batch, caches: Caches, state: SlotState,
                   slots, pos0, budget, eos):
        logits, fresh = prefill_step(params, batch)
        # admission is greedy: the prompt's continuation token
        if mask is not None:
            logits = logits + mask.astype(logits.dtype)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        def merge(old, new):
            return old.at[:, slots].set(new.astype(old.dtype))

        kv = jax.tree.map(merge, caches.kv, fresh.kv)
        ssm = jax.tree.map(merge, caches.ssm, fresh.ssm)
        cross = caches.cross
        if cross is not None and fresh.cross is not None:
            cross = jax.tree.map(merge, cross, fresh.cross)
        # the admission token already counts toward the budget; a slot with
        # nothing left (or an immediate EOS) never activates
        remaining = budget - 1
        state = SlotState(
            tokens=state.tokens.at[slots].set(nxt),
            cur_pos=state.cur_pos.at[slots].set(pos0),
            active=state.active.at[slots].set(
                (remaining > 0) & (nxt != eos)
            ),
            remaining=state.remaining.at[slots].set(remaining),
            eos=state.eos.at[slots].set(eos),
        )
        return nxt, Caches(kv=kv, ssm=ssm, cross=cross), state

    return admit_step


# ---------------------------------------------------------------------------
# Paged KV: on-device page tables, free-list and page-fault allocation
# ---------------------------------------------------------------------------


class PageState(NamedTuple):
    """Device-resident page-pool bookkeeping, donated alongside the caches.

    table:    (B, max_pages) int32 — physical page backing each slot's
              logical page (absolute positions [j*ps, (j+1)*ps)); -1 =
              unmapped.  A physical page is mapped by at most one
              (slot, logical) entry — the no-double-mapping invariant.
    free:     (n_pages + 1,) int32 — stack of free page ids; entries
              [0, free_top) are valid, the last element is scratch for
              masked-out pushes (mirrors the trash page of the pool).
    free_top: () int32 — stack pointer; allocated pages = n_pages - free_top.
    quota:    () int32 — lease cap on allocated pages (the hypervisor's
              ``kv_pages`` dimension); a fault beyond it is denied even if
              the pool has free pages.
    pinned:   (B,) int32 — leading logical pages of each slot's row that are
              owned by the **prefix cache** (shared, read-only): a finishing
              slot never pushes them back onto the free stack — the host's
              refcount ledger decides when a shared page becomes free.
              Decode never writes them either, by construction: the write
              position's logical page is ``cur_pos // page_size >= pinned``.
    """

    table: jax.Array
    free: jax.Array
    free_top: jax.Array
    quota: jax.Array
    pinned: jax.Array

    @property
    def n_pages(self) -> int:
        return self.free.shape[0] - 1


def init_page_state(batch: int, n_pages: int, max_pages: int,
                    *, quota: Optional[int] = None) -> PageState:
    return PageState(
        table=jnp.full((batch, max_pages), -1, jnp.int32),
        free=jnp.concatenate([jnp.arange(n_pages, dtype=jnp.int32),
                              jnp.full((1,), -1, jnp.int32)]),
        free_top=jnp.int32(n_pages),
        quota=jnp.int32(n_pages if quota is None else min(quota, n_pages)),
        pinned=jnp.zeros((batch,), jnp.int32),
    )


def _free_finished_pages(pages_table, free, free_top, finished, pinned):
    """Push every *private* page mapped by a ``finished`` slot back onto the
    free stack (cumsum-ranked scatter; masked-out entries land on the
    scratch element) and clear those table rows.  The slot's first
    ``pinned`` logical pages are cache-owned (shared) and are NOT pushed —
    the host releases their refcounts at sync time.  Returns
    (table, free, free_top, pinned)."""
    scratch = free.shape[0] - 1
    maxp = pages_table.shape[1]
    private = jnp.arange(maxp, dtype=jnp.int32)[None, :] >= pinned[:, None]
    pmask = finished[:, None] & (pages_table >= 0) & private
    flat = pmask.reshape(-1)
    prank = jnp.cumsum(flat.astype(jnp.int32)) - 1
    idx = jnp.where(flat, free_top + prank, scratch)
    free = free.at[idx].set(pages_table.reshape(-1))
    free_top = free_top + flat.sum(dtype=jnp.int32)
    table = jnp.where(finished[:, None], -1, pages_table)
    pinned = jnp.where(finished, 0, pinned)
    return table, free, free_top, pinned


def make_paged_decode_chunk(cfg, scfg: ServeConfig, n_steps: int,
                            page_size: int, *, policy=None):
    """decode_chunk(params, caches, state, pages, key) ->
    (caches, state, pages, tokens (T, B), emitted (T, B), poisoned (B,),
    ctr (4,) int32).

    ``ctr`` is the chunk's device-counter vector — pages popped off the
    free stack, pages pushed back by in-scan frees, slot-steps denied a
    grant, and (speculative twin only; 0 here) draft tokens accepted —
    accumulated across the scan so the host-side telemetry sees in-chunk
    paging activity without an extra sync (it rides back in the same
    fetch as the tokens).

    The paged twin of :func:`make_decode_chunk`: same ``lax.scan`` with the
    same EOS/budget bookkeeping, plus **page faults handled inside the
    chunk boundary** — a slot whose write position crosses into an
    unmapped logical page pops a page from the device free stack before
    the decode step (so the batcher still pays ≤1 dispatch and ≤1 host
    sync per chunk).  Grants are prefix-ordered by slot index (both the
    stack bound and the quota bound are monotone in the cumsum rank, so a
    denied slot implies every later needer is denied too — pops stay
    contiguous at the top of the stack).  A denied slot (pool dry or
    quota hit) deactivates immediately without emitting — the host sees
    ``active`` drop without EOS/budget and requeues the request.  Pages
    of slots that finish (EOS, budget, denial, or the ``poisoned``
    NaN/inf sentinel — see :func:`make_decode_chunk`) are pushed back
    onto the stack in the same step, so capacity frees mid-chunk.  Jit
    with ``donate_argnums=(1, 2, 3)``.
    """
    mask = scfg.logit_mask(cfg)
    ps = int(page_size)

    def decode_chunk(params, caches: Caches, state: SlotState,
                     pages: PageState, key):
        n_pages = pages.free.shape[0] - 1
        B = state.tokens.shape[0]
        bidx = jnp.arange(B)

        def body(carry, _):
            caches, st, pg, key, poisoned, ctr = carry
            key, sub = jax.random.split(key)
            # -- page fault: map the write position's logical page --------
            logical = (st.cur_pos // ps).astype(jnp.int32)
            cur_pid = jnp.take_along_axis(pg.table, logical[:, None], axis=1)[:, 0]
            need = st.active & (cur_pid < 0)
            rank = jnp.cumsum(need.astype(jnp.int32)) - 1
            allocated = n_pages - pg.free_top
            got = need & (rank < pg.free_top) & (allocated + rank < pg.quota)
            pid = pg.free[jnp.clip(pg.free_top - 1 - rank, 0, n_pages)]
            table = pg.table.at[bidx, logical].set(
                jnp.where(got, pid, cur_pid))
            popped = got.sum(dtype=jnp.int32)
            free_top = pg.free_top - popped
            oom = need & ~got
            active = st.active & ~oom
            # -- decode against the (updated) page table ------------------
            logits, caches = decode_step(
                params, st.tokens, caches, st.cur_pos, cfg,
                impl=scfg.attn_impl, policy=policy, page_table=table,
            )
            bad = active & ~jnp.isfinite(logits).all(axis=-1)
            active = active & ~bad
            nxt = select_token(logits, mask, scfg, sub)
            nxt = jnp.where(active, nxt, st.tokens)
            emitted = active
            remaining = st.remaining - active.astype(jnp.int32)
            done = active & ((nxt == st.eos) | (remaining <= 0))
            # -- recycle pages of finished slots --------------------------
            ft_pop = free_top
            table, free, free_top, pinned = _free_finished_pages(
                table, pg.free, ft_pop, done | oom | bad, pg.pinned)
            ctr = ctr + jnp.stack(
                [popped, free_top - ft_pop, oom.sum(dtype=jnp.int32),
                 jnp.int32(0)])
            st = SlotState(
                tokens=nxt,
                cur_pos=st.cur_pos + active.astype(jnp.int32),
                active=active & ~done,
                remaining=remaining,
                eos=st.eos,
            )
            pg = PageState(table=table, free=free, free_top=free_top,
                           quota=pg.quota, pinned=pinned)
            return (caches, st, pg, key, poisoned | bad, ctr), (nxt, emitted)

        poisoned0 = jnp.zeros((B,), bool)
        ctr0 = jnp.zeros((4,), jnp.int32)
        (caches, state, pages, _, poisoned, ctr), (toks, emitted) = \
            jax.lax.scan(
                body, (caches, state, pages, key, poisoned0, ctr0), None,
                length=n_steps
            )
        return caches, state, pages, toks, emitted, poisoned, ctr

    return decode_chunk


def _grant_admission_pages(pages: PageState, ask, np_: int):
    """Prefix-feasible page grants for one admission batch: every asking
    row needs ``np_`` pages.  ``cum`` is monotone, so stack/quota denials
    only ever cut a suffix — pops stay contiguous at the stack top.
    Shared by the cold and cached admit programs (one discipline, edited
    once).  Returns (ok, grant, pid (n, np_), dest, free_top)."""
    n_pages = pages.free.shape[0] - 1
    cum = jnp.cumsum(ask.astype(jnp.int32)) * np_
    allocated = n_pages - pages.free_top
    ok = (cum <= pages.free_top) & (allocated + cum <= pages.quota)
    grant = ask & ok
    ranks = ((jnp.cumsum(grant.astype(jnp.int32)) - 1)[:, None] * np_
             + jnp.arange(np_, dtype=jnp.int32)[None, :])          # (n, np_)
    pid = pages.free[jnp.clip(pages.free_top - 1 - ranks, 0, n_pages)]
    dest = jnp.where(grant[:, None], pid, n_pages)                 # trash
    free_top = pages.free_top - grant.sum(dtype=jnp.int32) * np_
    return ok, grant, pid, dest, free_top


def _scatter_fresh_kv(caches_kv, fresh_kv, dest, *, S: int, np_: int,
                      ps: int, n: int):
    """Scatter freshly-prefilled K/V (per layer: (nb, n, S, Hkv, dh)) into
    the popped pool pages at ``dest`` ((n, np_); trash for denied rows).
    ``fresh_kv`` maps layer key -> (k, v)."""
    pad = np_ * ps - S

    def to_pages(a):
        # (nb, n, S, ...) -> (nb, n * np_, ps, ...)
        if pad:
            width = ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 3)
            a = jnp.pad(a, width)
        return a.reshape(a.shape[0], n * np_, ps, *a.shape[3:])

    def scatter(old, new):
        return old.at[:, dest.reshape(-1)].set(to_pages(new).astype(old.dtype))

    return {
        p: type(view)(k=scatter(view.k, fresh_kv[p][0]),
                      v=scatter(view.v, fresh_kv[p][1]))
        for p, view in caches_kv.items()
    }


def make_paged_admit_step(cfg, scfg: ServeConfig, *, policy=None):
    """admit_step(params, batch, caches, state, pages, slots, pos0, budget,
    eos, real, pin) -> (first_tokens (n,), caches, state, pages, rows).

    Paged admission: right-sized bucketed prefill exactly like
    :func:`make_admit_step`, but the fresh K/V is scattered into
    **freshly-popped pool pages** instead of per-slot dense rows, and the
    joining slots' page-table rows are rewritten.  ``real`` (n,) bool marks
    genuine rows — bucket padding duplicates row 0 and must neither pop
    pages nor write conflicting values (every duplicate scatter carries row
    0's values, keeping the duplicate-index writes deterministic).  A row
    that never activates (immediate EOS / zero budget / allocation denied)
    gets no pages and a cleared table row.  ``pin`` (n,) int32 is the
    prefix-cache pin plan: how many of the row's leading logical pages the
    host will insert into the shared prefix cache after the sync (0 when
    prefix caching is off) — recorded in ``PageState.pinned`` so the chunk
    scan never recycles them.  ``rows`` returns the written page-table rows
    so the host learns the physical ids it is about to share.  Jit with
    ``donate_argnums=(2, 3, 4)``.
    """
    mask = scfg.logit_mask(cfg)

    def admit_step(params, batch, caches: Caches, state: SlotState,
                   pages: PageState, slots, pos0, budget, eos, real, pin):
        ps = None
        for view in caches.kv.values():
            ps = view.k.shape[2]
            break
        assert ps is not None, "paged admission needs at least one attn layer"
        kw: Dict[str, Any] = dict(impl=scfg.attn_impl, policy=policy)
        S = batch["tokens"].shape[1]
        if cfg.family == "vlm":
            kw["extra_embeds"] = batch["extra_embeds"]
            kw["positions"] = batch["positions"]
            S += batch["extra_embeds"].shape[1]
        if cfg.family == "audio":
            kw["enc_out"] = encoder_forward(
                params, batch["frames"], cfg, impl=scfg.attn_impl, policy=policy
            )
        # seed a dense cache sized exactly to the prompt: identity placement,
        # so fresh K/V rows are in absolute-position order for page packing
        logits, fresh = prefill(params, batch["tokens"], cfg, max_len=S, **kw)
        if mask is not None:
            logits = logits + mask.astype(logits.dtype)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        n = nxt.shape[0]
        np_ = pages_for(S, ps)
        maxp = pages.table.shape[1]
        remaining = budget - 1
        wants = (remaining > 0) & (nxt != eos)
        ask = real & wants
        ok, grant, pid, dest, free_top = _grant_admission_pages(
            pages, ask, np_)

        # page-table rows: granted rows map their np_ pages, everything else
        # clears; padding rows carry row 0's values (duplicate-scatter rule)
        row = jnp.full((n, maxp), -1, jnp.int32).at[:, :np_].set(
            jnp.where(grant[:, None], pid, -1))
        row = jnp.where(real[:, None], row, row[0:1])
        table = pages.table.at[slots].set(row)

        kv = _scatter_fresh_kv(
            caches.kv, {p: (fresh.kv[p].k, fresh.kv[p].v) for p in caches.kv},
            dest, S=S, np_=np_, ps=ps, n=n)

        def merge(old, new):
            return old.at[:, slots].set(new.astype(old.dtype))

        ssm = jax.tree.map(merge, caches.ssm, fresh.ssm)
        cross = caches.cross
        if cross is not None and fresh.cross is not None:
            cross = jax.tree.map(merge, cross, fresh.cross)

        activates = wants & (ok | (np_ == 0))
        act_vals = jnp.where(real, activates, activates[0])
        # pin plan only sticks for rows that really mapped their pages;
        # padding rows carry row 0's value (duplicate-scatter rule)
        pin_vals = jnp.where(grant, jnp.clip(pin, 0, np_), 0)
        pin_vals = jnp.where(real, pin_vals, pin_vals[0])
        state = SlotState(
            tokens=state.tokens.at[slots].set(nxt),
            cur_pos=state.cur_pos.at[slots].set(pos0),
            active=state.active.at[slots].set(act_vals),
            remaining=state.remaining.at[slots].set(remaining),
            eos=state.eos.at[slots].set(eos),
        )
        pages = PageState(table=table, free=pages.free, free_top=free_top,
                          quota=pages.quota,
                          pinned=pages.pinned.at[slots].set(pin_vals))
        return nxt, Caches(kv=kv, ssm=ssm, cross=cross), state, pages, row

    return admit_step


def paged_decode_chunk_program(cfg, scfg: ServeConfig, n_steps: int,
                               page_size: int, *, policy=None, mesh=None):
    """Jitted :func:`make_paged_decode_chunk`, caches/state/pages donated.
    Sharded under ``mesh``: the page pool's head axis splits, the page-fault
    machinery (tables, free stack, grants) is replicated — every shard pops
    the same pages, writes its own heads into them."""
    if mesh is not None:
        return _tp_program(
            "paged_chunk", cfg, scfg, (int(n_steps), int(page_size)),
            policy, mesh,
            lambda lcfg: make_paged_decode_chunk(lcfg, scfg, n_steps,
                                                 page_size, policy=policy),
            paged=True, n_in=5, cache_in=1, n_out=7, cache_out=0,
            donate=(1, 2, 3))
    return PROGRAMS.get(
        "paged_chunk", cfg, scfg, (int(n_steps), int(page_size)), policy,
        lambda: jax.jit(
            make_paged_decode_chunk(cfg, scfg, n_steps, page_size,
                                    policy=policy),
            donate_argnums=(1, 2, 3)),
    )


def paged_admit_program(cfg, scfg: ServeConfig, *, policy=None, mesh=None):
    """Jitted :func:`make_paged_admit_step`, caches/state/pages donated."""
    if mesh is not None:
        return _tp_program(
            "paged_admit", cfg, scfg, (), policy, mesh,
            lambda lcfg: make_paged_admit_step(lcfg, scfg, policy=policy),
            paged=True, n_in=11, cache_in=2, n_out=5, cache_out=1,
            donate=(2, 3, 4))
    return PROGRAMS.get(
        "paged_admit", cfg, scfg, (), policy,
        lambda: jax.jit(make_paged_admit_step(cfg, scfg, policy=policy),
                        donate_argnums=(2, 3, 4)),
    )


def make_cached_admit_step(cfg, scfg: ServeConfig, n_prefix_pages: int,
                           *, policy=None):
    """admit_step(params, batch, caches, state, pages, slots, pos0, budget,
    eos, real, prefix_pids, pin) -> (first_tokens, caches, state, pages,
    rows) — shared-prefix admission.

    The cached twin of :func:`make_paged_admit_step` for rows whose prompt's
    first ``n_prefix_pages`` logical pages are already resident in the
    prefix cache: ``batch["tokens"]`` carries only the **uncached suffix**
    (``prompt_len - n_prefix_pages * page_size`` tokens), the cached pages'
    K/V is gathered from the pool and attended to as a prefix context
    (:func:`repro.models.prefix_prefill`), and only the suffix pages are
    popped from the free stack.  ``prefix_pids`` (n, n_prefix_pages) are the
    cached physical page ids, mapped **read-only** into the joining slot's
    table row — the copy-on-write discipline: the divergent tail (at
    minimum the page holding the last prompt token — the prefix is capped
    at ``(prompt_len - 1) // page_size`` pages, so a *fully* cached prompt
    still prefills its last page privately) always writes private pages,
    shared pages are never written.  ``pin`` (n,) counts the row's leading
    cache-owned logical pages (hits + the host's planned inserts), recorded
    in ``PageState.pinned``.  Bucketing/padding rules are identical to the
    cold program.  Jit with ``donate_argnums=(2, 3, 4)``.
    """
    mask = scfg.logit_mask(cfg)
    kp = int(n_prefix_pages)
    assert kp >= 1, "use the cold paged admit program for zero cached pages"

    def admit_step(params, batch, caches: Caches, state: SlotState,
                   pages: PageState, slots, pos0, budget, eos, real,
                   prefix_pids, pin):
        ps = None
        for view in caches.kv.values():
            ps = view.k.shape[2]
            break
        assert ps is not None, "cached admission needs at least one attn layer"
        Lp = kp * ps
        n, S = batch["tokens"].shape                       # S = suffix length

        # cached prefix context: pool pages -> (nb, n, Lp, Hkv, dh) per layer
        def gather(a):
            g = a[:, prefix_pids]                          # (nb,n,kp,ps,H,dh)
            return g.reshape(g.shape[0], n, Lp, *g.shape[4:])

        prefix_kv = {p: (gather(view.k), gather(view.v))
                     for p, view in caches.kv.items()}
        logits, ys = prefix_prefill(
            params, batch["tokens"], prefix_kv, cfg, prefix_len=Lp,
            impl=scfg.attn_impl, policy=policy,
        )
        if mask is not None:
            logits = logits + mask.astype(logits.dtype)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        np_ = pages_for(S, ps)                             # private pages
        maxp = pages.table.shape[1]
        remaining = budget - 1
        wants = (remaining > 0) & (nxt != eos)
        ask = real & wants
        ok, grant, pid, dest, free_top = _grant_admission_pages(
            pages, ask, np_)

        # table row: [shared prefix (read-only) | fresh suffix | -1 ...]
        row = jnp.full((n, maxp), -1, jnp.int32)
        row = row.at[:, :kp].set(jnp.where(grant[:, None], prefix_pids, -1))
        row = row.at[:, kp:kp + np_].set(jnp.where(grant[:, None], pid, -1))
        row = jnp.where(real[:, None], row, row[0:1])
        table = pages.table.at[slots].set(row)

        kv = _scatter_fresh_kv(caches.kv, ys, dest, S=S, np_=np_, ps=ps, n=n)

        activates = wants & ok
        act_vals = jnp.where(real, activates, activates[0])
        pin_vals = jnp.where(grant, jnp.clip(pin, kp, kp + np_), 0)
        pin_vals = jnp.where(real, pin_vals, pin_vals[0])
        state = SlotState(
            tokens=state.tokens.at[slots].set(nxt),
            cur_pos=state.cur_pos.at[slots].set(pos0),
            active=state.active.at[slots].set(act_vals),
            remaining=state.remaining.at[slots].set(remaining),
            eos=state.eos.at[slots].set(eos),
        )
        pages = PageState(table=table, free=pages.free, free_top=free_top,
                          quota=pages.quota,
                          pinned=pages.pinned.at[slots].set(pin_vals))
        return (nxt, Caches(kv=kv, ssm=caches.ssm, cross=caches.cross),
                state, pages, row)

    return admit_step


def cached_admit_program(cfg, scfg: ServeConfig, n_prefix_pages: int,
                         *, policy=None, mesh=None):
    """Jitted :func:`make_cached_admit_step`, caches/state/pages donated.
    One executable per (arch × serve shape × prefix-page count) — the
    prefix-page counts are bounded by ``prompt_len / page_size``, so the
    program cache stays small."""
    if mesh is not None:
        return _tp_program(
            "cached_admit", cfg, scfg, (int(n_prefix_pages),), policy, mesh,
            lambda lcfg: make_cached_admit_step(lcfg, scfg, n_prefix_pages,
                                                policy=policy),
            paged=True, n_in=12, cache_in=2, n_out=5, cache_out=1,
            donate=(2, 3, 4))
    return PROGRAMS.get(
        "cached_admit", cfg, scfg, (int(n_prefix_pages),), policy,
        lambda: jax.jit(
            make_cached_admit_step(cfg, scfg, n_prefix_pages, policy=policy),
            donate_argnums=(2, 3, 4)),
    )


def make_page_push():
    """push(pages, pids (K,)) -> pages — return evicted prefix-cache pages
    (host decision: refcount hit 0 and the LRU chose them) to the device
    free stack.  ``pids`` entries < 0 are padding.  Jit with
    ``donate_argnums=(0,)``."""

    def push(pages: PageState, pids):
        scratch = pages.free.shape[0] - 1
        valid = pids >= 0
        rank = jnp.cumsum(valid.astype(jnp.int32)) - 1
        idx = jnp.where(valid, pages.free_top + rank, scratch)
        free = pages.free.at[idx].set(pids)
        return pages._replace(
            free=free, free_top=pages.free_top + valid.sum(dtype=jnp.int32))

    return push


def page_push_program():
    """Jitted :func:`make_page_push` (page state donated); one cached
    executable, re-traced per pid-vector shape by jit itself."""
    return PROGRAMS.get(
        "page_push", None, None, (), None,
        lambda: jax.jit(make_page_push(), donate_argnums=(0,)),
    )


# ---------------------------------------------------------------------------
# Speculative decode: n-gram drafting + batched verify inside the chunk scan
# ---------------------------------------------------------------------------
#
# The chunked scan's unit of work changes from one token to one **window**:
# a drafter proposes ``W - 1`` continuation tokens per slot from an
# on-device n-gram history, a single multi-query ``verify_step`` scores the
# committed token plus all drafts in one pass (W query positions instead of
# 1), and accept/rollback bookkeeping commits the longest draft prefix the
# greedy model agrees with, plus one bonus token.  Greedy acceptance is
# exact: position ``w`` of the verify logits is conditioned on exactly the
# tokens sequential greedy decode would have seen iff drafts ``1..w`` all
# matched — so the committed tokens are **token-identical to non-speculative
# greedy decode by construction**, and the win is purely dispatch/bandwidth
# (one cache sweep serves W positions).
#
# Rollback is *overwrite-before-attend*, not state surgery: rejected
# positions' KV writes are left in place (dense: masked beyond the budget so
# the ring never wraps onto live context; paged: stale offsets in mapped
# pages), ``cur_pos`` rewinds by simply not advancing past the commit point,
# and the next window rewrites every stale position before any query can
# attend to it (the window always spans at least as far as the previous
# window's overshoot).  Likewise "page-table rewind" for rejected tokens:
# pages mapped for the overshoot are *retained* as prefetched capacity —
# they are exactly the pages the next window needs — and are recycled by
# ``_free_finished_pages`` the moment the slot finishes.


class DraftState(NamedTuple):
    """On-device n-gram drafter history, donated alongside the caches.

    hist: (B, N) int32 — last ``N`` committed tokens per slot, newest at
          index ``N - 1``, front-padded with -1 (never a valid token, so
          padding cannot match).
    n:    (B,) int32 — count of valid entries (≤ N).
    """

    hist: jax.Array
    n: jax.Array


def init_draft_state(batch: int, hist_len: int) -> DraftState:
    return DraftState(
        hist=jnp.full((batch, hist_len), -1, jnp.int32),
        n=jnp.zeros((batch,), jnp.int32),
    )


def _propose_drafts(draft: DraftState, last, n_draft: int, ngram: int):
    """(B, n_draft) draft tokens: find the most recent earlier occurrence of
    the trailing ``ngram`` committed tokens and propose its continuation;
    slots with no match fall back to repeating the last token (free to
    verify — the window runs at fixed width W regardless)."""
    hist, n = draft.hist, draft.n
    B, N = hist.shape
    idx = jnp.arange(N, dtype=jnp.int32)
    m = jnp.ones((B, N), bool)
    for g in range(ngram):
        # shifted[:, i] = hist[:, i - g] (−1 beyond the front): candidate
        # n-gram *ending* at i matches the trailing n-gram ending at N-1
        shifted = (hist if g == 0 else
                   jnp.pad(hist, ((0, 0), (g, 0)),
                           constant_values=-1)[:, :N])
        m = m & (shifted == hist[:, N - 1 - g][:, None])
    # candidate must end strictly before the trailing n-gram and span only
    # valid history: i - ngram + 1 >= N - n
    m = m & (idx[None, :] < N - 1) & (idx[None, :] >= (N - n + ngram - 1)[:, None])
    match_idx = jnp.max(jnp.where(m, idx[None, :], -1), axis=1)       # (B,)
    found = (match_idx >= 0) & (n >= ngram + 1)
    cont = jnp.clip(
        match_idx[:, None] + 1 + jnp.arange(n_draft, dtype=jnp.int32)[None, :],
        0, N - 1)
    proposed = jnp.take_along_axis(hist, cont, axis=1)
    fallback = jnp.broadcast_to(last[:, None], (B, n_draft))
    return jnp.where(found[:, None], proposed, fallback).astype(jnp.int32)


def _advance_draft(draft: DraftState, toks, c):
    """Shift ``c[b]`` committed tokens (``toks[b, :c[b]]``) into each slot's
    history.  Gather indices never reach past position ``N - 1 + c[b]`` of
    the concatenation, so uncommitted window tokens are never read."""
    hist, n = draft.hist, draft.n
    N = hist.shape[1]
    ext = jnp.concatenate([hist, toks.astype(jnp.int32)], axis=1)
    idx = jnp.arange(N, dtype=jnp.int32)[None, :] + c[:, None]
    return DraftState(
        hist=jnp.take_along_axis(ext, idx, axis=1),
        n=jnp.minimum(n + c, N).astype(jnp.int32),
    )


def _spec_accept(q_toks, g, st: SlotState, active):
    """The acceptance algebra shared by the dense and paged spec chunks.

    ``g[b, w]`` is the greedy token given the prefix through ``q_toks[b, w]``
    — valid as a sequential-greedy output iff drafts ``1..w`` all matched,
    which is exactly what the cumulative-product acceptance scan checks, so
    garbage positions (wrong-context logits after the first mismatch) can
    never be committed.  Returns (c, nxt, done, emitted):

      c       (B,) int32 — committed tokens this window: the accepted draft
              prefix + 1 bonus token, cut at the first EOS and at the
              remaining budget; ≥ 1 for active slots (the bonus token is
              unconditional, mirroring one non-speculative step).
      nxt     (B,) int32 — last committed token (next window's root).
      done    (B,) bool  — EOS committed or budget exhausted.
      emitted (B, W) bool — prefix mask ``w < c`` over the window outputs.
    """
    W = g.shape[1]
    wi = jnp.arange(W, dtype=jnp.int32)
    acc = (q_toks[:, 1:] == g[:, :-1]).astype(jnp.int32)       # (B, W-1)
    e = 1 + jnp.cumprod(acc, axis=1).sum(axis=1)               # (B,) in [1,W]
    is_eos = (st.eos[:, None] >= 0) & (g == st.eos[:, None])
    fe = jnp.where(is_eos.any(axis=1),
                   jnp.argmax(is_eos, axis=1), W)              # first EOS
    # EOS beyond the accepted prefix (fe >= e) is a garbage-position token
    # and is correctly ignored: c = min(e, ...) cuts before it
    c = jnp.minimum(jnp.minimum(e, fe + 1), st.remaining)
    c = jnp.where(active, c, 0)
    hit_eos = fe < c                 # ⟺ the EOS is the last committed token
    done = active & (hit_eos | (st.remaining - c <= 0))
    nxt = jnp.take_along_axis(g, jnp.clip(c - 1, 0, W - 1)[:, None],
                              axis=1)[:, 0]
    nxt = jnp.where(active, nxt, st.tokens)
    emitted = active[:, None] & (wi[None, :] < c[:, None])
    return c, nxt, done, emitted


def make_spec_decode_chunk(cfg, scfg: ServeConfig, n_windows: int,
                           window: int, ngram: int, *, policy=None):
    """spec_chunk(params, caches, state, draft, key) ->
    (caches, state, draft, tokens (Tw, B, W), emitted (Tw, B, W), poisoned).

    The speculative twin of :func:`make_decode_chunk`: ``n_windows``
    draft-and-verify windows of width ``window`` per dispatch.  Greedy only
    — acceptance compares argmax tokens, which is meaningless under
    sampling.  ``emitted`` is a per-window *prefix* mask (the committed
    tokens are ``tokens[t, b, :c]``); the poison sentinel discards the whole
    window for a slot whose committable logits come back non-finite.  The
    dense ring writes are masked at the remaining budget (``write_limit``)
    so overshoot writes can never wrap the ring onto live context.  Jit
    with ``donate_argnums=(1, 2, 3)``.
    """
    assert scfg.greedy, "speculative decode requires greedy selection"
    mask = scfg.logit_mask(cfg)
    W = int(window)

    def spec_chunk(params, caches: Caches, state: SlotState,
                   draft: DraftState, key):
        del key  # greedy: kept for signature parity with the sampled chunk
        B = state.tokens.shape[0]
        wi = jnp.arange(W, dtype=jnp.int32)

        def body(carry, _):
            caches, st, dr, poisoned = carry
            drafts = _propose_drafts(dr, st.tokens, W - 1, ngram)
            q_toks = jnp.concatenate([st.tokens[:, None], drafts], axis=1)
            logits, caches = verify_step(
                params, q_toks, caches, st.cur_pos, cfg,
                impl=scfg.attn_impl, policy=policy,
                write_limit=st.remaining,
            )
            if mask is not None:
                logits = logits + mask.astype(logits.dtype)
            g = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B, W)
            # poison only on committable positions: beyond the remaining
            # budget the ring write was masked and the query may read stale
            # slots — garbage there is expected and can never be emitted
            finite = jnp.isfinite(logits).all(axis=-1)          # (B, W)
            committable = wi[None, :] < st.remaining[:, None]
            bad = st.active & ~(finite | ~committable).all(axis=1)
            active = st.active & ~bad
            c, nxt, done, emitted = _spec_accept(q_toks, g, st, active)
            dr = _advance_draft(dr, g, c)
            st = SlotState(
                tokens=nxt,
                cur_pos=st.cur_pos + c,
                active=active & ~done,
                remaining=st.remaining - c,
                eos=st.eos,
            )
            return (caches, st, dr, poisoned | bad), (g, emitted)

        poisoned0 = jnp.zeros((B,), bool)
        (caches, state, draft, poisoned), (toks, emitted) = jax.lax.scan(
            body, (caches, state, draft, poisoned0), None, length=n_windows
        )
        return caches, state, draft, toks, emitted, poisoned

    return spec_chunk


def make_paged_spec_decode_chunk(cfg, scfg: ServeConfig, n_windows: int,
                                 window: int, ngram: int, page_size: int,
                                 *, policy=None):
    """spec_chunk(params, caches, state, pages, draft, key) ->
    (caches, state, pages, draft, tokens (Tw, B, W), emitted, poisoned,
    ctr (4,) int32).

    ``ctr`` = (pages popped, pages pushed, fault-denied slots, draft
    tokens accepted), accumulated in-scan — the same device-counter
    vector :func:`make_paged_decode_chunk` returns, with the speculative
    accept count in the last slot so telemetry sees per-window acceptance
    without an extra sync.

    Paged speculative chunk: the page fault inside the scan maps **every
    logical page the window's committable span touches** (up to
    ``(W - 2) // page_size + 2`` pages), all-or-nothing per slot — a slot
    that cannot map its full span is denied and requeued like a single-page
    OOM, so a half-mapped window can never commit tokens whose KV landed in
    the trash page.  Grants stay prefix-feasible: the per-slot page need is
    cumsum-ranked, both the stack bound and the quota bound are monotone in
    that rank, so denials cut a suffix and pops stay contiguous at the top
    of the stack.  Overshoot pages are retained (they are the next window's
    pages) and recycled by :func:`_free_finished_pages` when the slot
    finishes.  Jit with ``donate_argnums=(1, 2, 3, 4)``.
    """
    assert scfg.greedy, "speculative decode requires greedy selection"
    mask = scfg.logit_mask(cfg)
    W = int(window)
    ps = int(page_size)
    # max logical pages [cur, cur + W - 1] can span: the first page may be
    # entered mid-page, every later one is full
    max_span = (W - 2) // ps + 2

    def spec_chunk(params, caches: Caches, state: SlotState,
                   pages: PageState, draft: DraftState, key):
        del key  # greedy: kept for signature parity with the sampled chunk
        n_pages = pages.free.shape[0] - 1
        B = state.tokens.shape[0]
        maxp = pages.table.shape[1]
        bidx = jnp.arange(B)
        wi = jnp.arange(W, dtype=jnp.int32)

        def body(carry, _):
            caches, st, pg, dr, poisoned, ctr = carry
            # -- multi-page fault over the window's committable span -------
            weff = jnp.minimum(W, st.remaining)      # positions that can
            l0 = (st.cur_pos // ps).astype(jnp.int32)  # ever be committed
            l1 = ((st.cur_pos + jnp.maximum(weff, 1) - 1) // ps).astype(
                jnp.int32)
            span = l0[:, None] + jnp.arange(max_span, dtype=jnp.int32)[None, :]
            in_span = span <= l1[:, None]
            col = jnp.clip(span, 0, maxp - 1)
            cur = jnp.take_along_axis(pg.table, col, axis=1)  # (B, max_span)
            need = st.active[:, None] & in_span & (cur < 0)
            need_cnt = need.sum(axis=1)
            base = jnp.cumsum(need_cnt) - need_cnt
            allocated = n_pages - pg.free_top
            fits = ((base + need_cnt <= pg.free_top)
                    & (allocated + base + need_cnt <= pg.quota))
            got = (need_cnt > 0) & fits
            oom = st.active & (need_cnt > 0) & ~fits
            rank_in = jnp.cumsum(need.astype(jnp.int32), axis=1) - need
            flat_rank = base[:, None] + rank_in
            pop = need & got[:, None]
            pid = pg.free[jnp.clip(pg.free_top - 1 - flat_rank, 0, n_pages)]
            table = pg.table
            for s in range(max_span):
                table = table.at[bidx, col[:, s]].set(
                    jnp.where(pop[:, s], pid[:, s], cur[:, s]))
            popped = pop.sum(dtype=jnp.int32)
            free_top = pg.free_top - popped
            active = st.active & ~oom
            # -- draft + batched verify against the (updated) table --------
            drafts = _propose_drafts(dr, st.tokens, W - 1, ngram)
            q_toks = jnp.concatenate([st.tokens[:, None], drafts], axis=1)
            logits, caches = verify_step(
                params, q_toks, caches, st.cur_pos, cfg,
                impl=scfg.attn_impl, policy=policy, page_table=table,
            )
            if mask is not None:
                logits = logits + mask.astype(logits.dtype)
            g = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            # overshoot positions past the budget may write to / read from
            # unmapped (trash-redirected) pages — only committable positions
            # can poison
            finite = jnp.isfinite(logits).all(axis=-1)
            committable = wi[None, :] < st.remaining[:, None]
            bad = active & ~(finite | ~committable).all(axis=1)
            active = active & ~bad
            c, nxt, done, emitted = _spec_accept(q_toks, g, st, active)
            dr = _advance_draft(dr, g, c)
            # -- recycle pages of finished / denied / poisoned slots -------
            ft_pop = free_top
            table, free, free_top, pinned = _free_finished_pages(
                table, pg.free, ft_pop, done | oom | bad, pg.pinned)
            ctr = ctr + jnp.stack(
                [popped, free_top - ft_pop, oom.sum(dtype=jnp.int32),
                 jnp.maximum(c - 1, 0).sum(dtype=jnp.int32)])
            st = SlotState(
                tokens=nxt,
                cur_pos=st.cur_pos + c,
                active=active & ~done,
                remaining=st.remaining - c,
                eos=st.eos,
            )
            pg = PageState(table=table, free=free, free_top=free_top,
                           quota=pg.quota, pinned=pinned)
            return (caches, st, pg, dr, poisoned | bad, ctr), (g, emitted)

        poisoned0 = jnp.zeros((B,), bool)
        ctr0 = jnp.zeros((4,), jnp.int32)
        (caches, state, pages, draft, poisoned, ctr), (toks, emitted) = (
            jax.lax.scan(body,
                         (caches, state, pages, draft, poisoned0, ctr0),
                         None, length=n_windows))
        return caches, state, pages, draft, toks, emitted, poisoned, ctr

    return spec_chunk


def spec_decode_chunk_program(cfg, scfg: ServeConfig, n_windows: int,
                              window: int, ngram: int, *, policy=None,
                              mesh=None):
    """Jitted :func:`make_spec_decode_chunk`, caches/state/draft donated.
    Sharded under ``mesh``: the n-gram draft history is replicated (drafting
    and accept/rollback are identical per shard), only the verify pass's
    KV/head math splits."""
    if mesh is not None:
        return _tp_program(
            "spec_chunk", cfg, scfg,
            (int(n_windows), int(window), int(ngram)), policy, mesh,
            lambda lcfg: make_spec_decode_chunk(lcfg, scfg, n_windows,
                                                window, ngram,
                                                policy=policy),
            paged=False, n_in=5, cache_in=1, n_out=6, cache_out=0,
            donate=(1, 2, 3))
    return PROGRAMS.get(
        "spec_chunk", cfg, scfg, (int(n_windows), int(window), int(ngram)),
        policy,
        lambda: jax.jit(
            make_spec_decode_chunk(cfg, scfg, n_windows, window, ngram,
                                   policy=policy),
            donate_argnums=(1, 2, 3)),
    )


def paged_spec_decode_chunk_program(cfg, scfg: ServeConfig, n_windows: int,
                                    window: int, ngram: int, page_size: int,
                                    *, policy=None, mesh=None):
    """Jitted :func:`make_paged_spec_decode_chunk`, caches/state/pages/draft
    donated."""
    if mesh is not None:
        return _tp_program(
            "paged_spec_chunk", cfg, scfg,
            (int(n_windows), int(window), int(ngram), int(page_size)),
            policy, mesh,
            lambda lcfg: make_paged_spec_decode_chunk(
                lcfg, scfg, n_windows, window, ngram, page_size,
                policy=policy),
            paged=True, n_in=6, cache_in=1, n_out=8, cache_out=0,
            donate=(1, 2, 3, 4))
    return PROGRAMS.get(
        "paged_spec_chunk", cfg, scfg,
        (int(n_windows), int(window), int(ngram), int(page_size)), policy,
        lambda: jax.jit(
            make_paged_spec_decode_chunk(cfg, scfg, n_windows, window,
                                         ngram, page_size, policy=policy),
            donate_argnums=(1, 2, 3, 4)),
    )


# ---------------------------------------------------------------------------
# Host generate loop (chunked)
# ---------------------------------------------------------------------------


def generate(
    params, cfg, prompt_tokens, *, n_new: int, scfg: Optional[ServeConfig] = None,
    policy=None, extras: Optional[Dict[str, Any]] = None, seed: int = 0,
):
    """Prefill the prompt, then decode ``n_new`` tokens through the chunked
    path: the remaining budget is covered by power-of-two chunk buckets
    (at most ceil((n_new-1)/chunk) + log2(chunk) dispatches instead of
    n_new-1 — the bucketing bounds the jit cache).

    prompt_tokens: (B, S) int32.  Returns (B, n_new) int32.
    """
    B, S = prompt_tokens.shape
    scfg = scfg or ServeConfig(max_len=S + n_new)
    batch = {"tokens": prompt_tokens, **(extras or {})}
    prefill_step = jax.jit(make_prefill_step(cfg, scfg, policy=policy))
    logits, caches = prefill_step(params, batch)
    mask = scfg.logit_mask(cfg)
    if mask is not None:
        logits = logits + mask.astype(logits.dtype)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    offset = S
    if cfg.family == "vlm" and extras and "extra_embeds" in extras:
        offset = S + extras["extra_embeds"].shape[1]

    out = [tok[:, None]]
    left = n_new - 1
    state = SlotState(
        tokens=tok,
        cur_pos=jnp.full((B,), offset, jnp.int32),
        active=jnp.ones((B,), bool),
        remaining=jnp.full((B,), max(left, 0), jnp.int32),
        eos=jnp.full((B,), -1, jnp.int32),
    )
    key = jax.random.PRNGKey(seed)
    while left > 0:
        T = chunk_bucket(min(left, max(scfg.chunk, 1)))
        fn = decode_chunk_program(cfg, scfg, T, policy=policy)
        key, sub = jax.random.split(key)
        caches, state, toks, _, _ = fn(params, caches, state, sub)
        out.append(jnp.moveaxis(toks, 0, 1))
        left -= T
    return jnp.concatenate(out, axis=1)
