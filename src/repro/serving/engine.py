"""Inference engine: prefill/serve step factories and a host generate loop.

``prefill_step`` and ``serve_step`` are the two programs the dry-run lowers
for the inference cells (prefill_32k → prefill_step; decode_32k / long_500k
→ serve_step).  Both are pure functions of (params, inputs, caches) so the
tenancy layer can AOT-compile them per (arch × shape × lease size) — the
TPU-side "instruction frame package".
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import decode_step, encoder_forward, prefill
from repro.models.transformer import Caches


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int
    attn_impl: str = "xla"       # xla | pallas
    greedy: bool = True
    temperature: float = 1.0


def make_prefill_step(cfg, scfg: ServeConfig, *, policy=None):
    """prefill_step(params, batch) -> (last-token logits, Caches).

    batch: {"tokens": (B, S)} + family extras (extra_embeds/positions/frames).
    """

    def prefill_step(params, batch):
        kw: Dict[str, Any] = dict(impl=scfg.attn_impl, policy=policy)
        if cfg.family == "vlm":
            kw["extra_embeds"] = batch["extra_embeds"]
            kw["positions"] = batch["positions"]
        if cfg.family == "audio":
            kw["enc_out"] = encoder_forward(
                params, batch["frames"], cfg, impl=scfg.attn_impl, policy=policy
            )
        return prefill(params, batch["tokens"], cfg, max_len=scfg.max_len, **kw)

    return prefill_step


def make_serve_step(cfg, scfg: ServeConfig, *, policy=None):
    """serve_step(params, tokens (B,), caches, cur_pos (B,), key) ->
    (next_tokens (B,), logits, caches)."""

    def serve_step(params, tokens, caches: Caches, cur_pos, key):
        logits, caches = decode_step(
            params, tokens, caches, cur_pos, cfg, impl=scfg.attn_impl,
            policy=policy,
        )
        # mask vocab padding before selection
        logits = logits.at[..., cfg.vocab:].set(-jnp.inf) if cfg.vocab_padded > cfg.vocab else logits
        if scfg.greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(
                key, logits.astype(jnp.float32) / scfg.temperature, axis=-1
            ).astype(jnp.int32)
        return nxt, logits, caches

    return serve_step


def generate(
    params, cfg, prompt_tokens, *, n_new: int, scfg: Optional[ServeConfig] = None,
    policy=None, extras: Optional[Dict[str, Any]] = None, seed: int = 0,
):
    """Host loop: prefill the prompt, then decode ``n_new`` tokens greedily.

    prompt_tokens: (B, S) int32.  Returns (B, n_new) int32.
    """
    B, S = prompt_tokens.shape
    scfg = scfg or ServeConfig(max_len=S + n_new)
    batch = {"tokens": prompt_tokens, **(extras or {})}
    prefill_step = jax.jit(make_prefill_step(cfg, scfg, policy=policy))
    serve_step = jax.jit(make_serve_step(cfg, scfg, policy=policy))
    logits, caches = prefill_step(params, batch)
    if cfg.vocab_padded > cfg.vocab:
        logits = logits.at[..., cfg.vocab:].set(-jnp.inf)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    offset = S
    if cfg.family == "vlm" and extras and "extra_embeds" in extras:
        offset = S + extras["extra_embeds"].shape[1]
    out = [tok]
    key = jax.random.PRNGKey(seed)
    for i in range(n_new - 1):
        key, sub = jax.random.split(key)
        cur = jnp.full((B,), offset + i, dtype=jnp.int32)
        tok, _, caches = serve_step(params, tok, caches, cur, sub)
        out.append(tok)
    return jnp.stack(out, axis=1)
