"""Shared-prefix KV cache: a refcounted radix tree over the paged pool.

The paged pool (PR 4) already allows many-to-one page mapping — nothing in
``PageState.table`` says two slots may not point at the same physical page.
This module is the index that makes that safe and useful: requests that
share a prompt prefix (a tenant's system prompt, a few-shot preamble) map
the *same* physical pages read-only and prefill only their divergent tail —
the paper's two-stage discipline applied to serving state: the heavy static
artifact (the cached prefix pages) is reused, only the cheap dynamic part
(the suffix) is recompiled per request.

Structure
---------
A radix/trie at **page granularity**: one node per full page of prompt
tokens, keyed by that page's token tuple, so a root-to-node path spells a
page-aligned token prefix.  Trees are per **namespace** (a tenant, or a
namespace several tenants agree to share) — lookups never cross
namespaces, which is the isolation rule: sharing is opt-in by key.

Lifecycle discipline (who may recycle a page, and when):

* a node's ``page_id`` is a physical page of the tenant's
  :class:`~repro.serving.kv_cache.PagedKVPool`; while the node lives, the
  page is **off the device free stack** and billed once to the namespace
  (``PagedKVPool.share``);
* ``refcount`` counts requests currently mapping the page.  Admission
  :meth:`acquire`\\ s the hit path, completion/OOM-requeue
  :meth:`release`\\ s it.  A page is *recyclable only at refcount 0* — and
  even then it stays cached (its contents are the cache's value) until
  eviction;
* eviction is **LRU over unpinned leaf nodes**: pinned means
  ``refcount > 0`` anywhere below, leaf means no children (an interior
  node is unreachable-from-root once removed, so subtrees fall leaf-first);
* a **partially-filled last page is never shared**: only full pages are
  indexed, and the caller additionally caps the shareable prefix at
  ``(prompt_len - 1) // page_size`` pages so the page holding the last
  prompt token — the one a divergent continuation would write — is always
  private (copy-on-write by construction: shared pages are read-only, the
  divergent tail gets freshly-popped pages).

The tree is pure host bookkeeping (no JAX): physical ids flow in from the
admission program's returned table rows and flow out to the device only
through the batcher's eviction pushes.

Known limitation — **prompt-length alignment**: the batcher left-pads every
prompt to its ``prompt_len`` bucket, and cache keys (like RoPE positions)
are taken over the padded row.  Two prompts therefore share pages only when
their *total* lengths are equal — a shared system preamble followed by
tails of different lengths lands at different absolute positions and can
never hit.  Templated clients should pad their tails to a fixed length (or
the batcher's bucket should move to right-aligned prompts + per-request
position offsets — see ROADMAP "Serving scale-out").
"""

from __future__ import annotations

import dataclasses
import itertools
from collections import OrderedDict
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

Key = Tuple[int, ...]


@dataclasses.dataclass
class PrefixNode:
    """One full page of a cached prompt prefix."""

    key: Key                         # this page's page_size tokens
    page_id: int                     # physical pool page holding its K/V
    namespace: Hashable
    parent: Optional["PrefixNode"]
    children: Dict[Key, "PrefixNode"] = dataclasses.field(default_factory=dict)
    refcount: int = 0                # requests currently mapping the page
    last_used: int = 0               # LRU tick (lookup hit or release)

    @property
    def depth(self) -> int:
        """Logical page index this node backs (root children are page 0)."""
        d, n = 0, self.parent
        while n is not None:
            d, n = d + 1, n.parent
        return d

    def __repr__(self) -> str:  # compact, for test failures
        return (f"<page {self.page_id} depth {self.depth} "
                f"rc {self.refcount} ns {self.namespace!r}>")


@dataclasses.dataclass
class PrefixCacheStats:
    lookups: int = 0
    hits: int = 0                    # lookups that matched >= 1 page
    hit_pages: int = 0               # total pages served from the cache
    inserts: int = 0                 # nodes created
    evictions: int = 0               # nodes evicted (pages returned)

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.lookups, 1)


class PrefixCache:
    """Namespace-keyed radix tree of cached prompt-prefix pages."""

    def __init__(self, page_size: int) -> None:
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.page_size = int(page_size)
        self._roots: Dict[Hashable, Dict[Key, PrefixNode]] = {}
        self._tick = itertools.count(1)
        self.n_pages = 0             # live nodes == cached pages
        self.stats = PrefixCacheStats()
        # ghost index: page-path keys of prompts looked up before, WITHOUT
        # pages behind them — recurrence evidence for the insert heuristic
        # (indexing every single-use tail would evict useful entries)
        self._seen: "OrderedDict[Tuple[Hashable, int, bytes], bool]" = \
            OrderedDict()
        self.seen_cap = 4096

    # -- keys -----------------------------------------------------------
    def _page_keys(self, tokens: Sequence[int]) -> List[Key]:
        """Full-page token tuples of a (padded) prompt row."""
        toks = np.asarray(tokens).reshape(-1)
        ps = self.page_size
        return [tuple(int(t) for t in toks[i * ps:(i + 1) * ps])
                for i in range(len(toks) // ps)]

    def max_shareable(self, prompt_len: int) -> int:
        """Pages of a ``prompt_len`` prompt that may ever be shared: the
        page holding the last token stays private (COW tail)."""
        return max(0, (int(prompt_len) - 1) // self.page_size)

    # -- lookup / pin ---------------------------------------------------
    def lookup(self, namespace: Hashable, tokens: Sequence[int],
               *, max_pages: Optional[int] = None) -> List[PrefixNode]:
        """Longest cached page path for ``tokens`` in ``namespace`` (at most
        ``max_pages`` — callers pass :meth:`max_shareable`).  Stamps the
        path's LRU ticks.  Returns the node path, root-child first."""
        keys = self._page_keys(tokens)
        if max_pages is None:
            max_pages = self.max_shareable(len(np.asarray(tokens).reshape(-1)))
        keys = keys[:max(0, int(max_pages))]
        level = self._roots.get(namespace, {})
        path: List[PrefixNode] = []
        tick = next(self._tick)
        for key in keys:
            node = level.get(key)
            if node is None:
                break
            node.last_used = tick
            path.append(node)
            level = node.children
        self.stats.lookups += 1
        if path:
            self.stats.hits += 1
            self.stats.hit_pages += len(path)
        return path

    def note_seen(self, namespace: Hashable, tokens: Sequence[int],
                  *, max_pages: Optional[int] = None) -> int:
        """Ghost index: record this prompt's page paths and return how many
        *leading* pages had already been seen by an earlier call — the
        "this prefix recurs" evidence the batcher needs before spending
        cache pages on it (a prefix only ever seen once is a tail, and
        indexing tails evicts entries that would actually hit).  Bounded
        LRU over ``seen_cap`` keys; keys only, no pages held."""
        toks = np.asarray(tokens, dtype=np.int32).reshape(-1)
        ps = self.page_size
        if max_pages is None:
            max_pages = self.max_shareable(len(toks))
        keys = [(namespace, i, toks[:(i + 1) * ps].tobytes())
                for i in range(max(0, int(max_pages)))]
        depth = 0
        for key in keys:
            if key not in self._seen:
                break
            depth += 1
        for key in keys:
            if key in self._seen:
                self._seen.move_to_end(key)
            else:
                self._seen[key] = True
        while len(self._seen) > self.seen_cap:
            self._seen.popitem(last=False)
        return depth

    def acquire(self, nodes: Sequence[PrefixNode]) -> None:
        """Pin a hit path for one more in-flight request."""
        for node in nodes:
            node.refcount += 1

    def release(self, nodes: Sequence[PrefixNode]) -> None:
        """Unpin a path (request finished / was requeued).  Refcount-0 nodes
        stay cached; they merely become evictable."""
        tick = next(self._tick)
        for node in nodes:
            assert node.refcount > 0, f"release of unpinned {node!r}"
            node.refcount -= 1
            node.last_used = tick

    # -- insert ---------------------------------------------------------
    def insert(self, namespace: Hashable, tokens: Sequence[int],
               page_ids: Sequence[int], *, start_page: int,
               ) -> List[PrefixNode]:
        """Index freshly-prefilled pages: ``page_ids[i]`` backs logical page
        ``start_page + i`` of ``tokens``.  The path ``[0, start_page)`` must
        already be cached (inserts extend an existing path — the batcher
        guarantees this by inserting exactly its miss tail).  Skips keys
        already present (races within one scheduling round are resolved by
        whoever inserted first); returns only the nodes actually created,
        whose pages the caller must re-own (``PagedKVPool.share``)."""
        keys = self._page_keys(tokens)
        assert start_page + len(page_ids) <= len(keys), \
            "page_ids run past the prompt's full pages"
        level = self._roots.setdefault(namespace, {})
        parent: Optional[PrefixNode] = None
        for key in keys[:start_page]:
            parent = level.get(key)
            assert parent is not None, \
                "insert requires the leading path to be cached"
            level = parent.children
        created: List[PrefixNode] = []
        tick = next(self._tick)
        for i, pid in enumerate(page_ids):
            key = keys[start_page + i]
            node = level.get(key)
            if node is None:
                node = PrefixNode(key=key, page_id=int(pid),
                                  namespace=namespace, parent=parent,
                                  last_used=tick)
                level[key] = node
                created.append(node)
                self.n_pages += 1
                self.stats.inserts += 1
            parent = node
            level = node.children
        return created

    # -- evict ----------------------------------------------------------
    def _leaves(self) -> List[PrefixNode]:
        out = []
        stack = [n for roots in self._roots.values() for n in roots.values()]
        while stack:
            node = stack.pop()
            if node.children:
                stack.extend(node.children.values())
            elif node.refcount == 0:
                out.append(node)
        return out

    def evictable_pages(self) -> int:
        """Upper bound on pages reclaimable *right now* (refcount-0 leaves;
        evicting them may expose more — the true total is every page on a
        fully-unpinned subtree, which :meth:`evict` reaches iteratively)."""
        return len(self._leaves())

    def evict(self, n_pages: int) -> List[int]:
        """Reclaim up to ``n_pages`` pages, LRU-first over unpinned leaves
        (re-collecting after each round, so an emptied interior node becomes
        eligible).  Returns the physical ids now free — the caller must
        ``drop_shared`` them from the ledger and push them back onto the
        device free stack."""
        freed: List[int] = []
        while len(freed) < n_pages:
            leaves = sorted(self._leaves(), key=lambda n: n.last_used)
            if not leaves:
                break
            for node in leaves[: n_pages - len(freed)]:
                if node.parent is None:
                    del self._roots[node.namespace][node.key]
                else:
                    del node.parent.children[node.key]
                self.n_pages -= 1
                self.stats.evictions += 1
                freed.append(node.page_id)
        return freed

    def check(self) -> None:
        """Structural invariants (tests): node count matches ``n_pages``,
        refcounts non-negative, every child's parent link is consistent."""
        count = 0
        for roots in self._roots.values():
            stack = [(None, n) for n in roots.values()]
            while stack:
                parent, node = stack.pop()
                assert node.parent is parent, f"parent drift at {node!r}"
                assert node.refcount >= 0, f"negative refcount {node!r}"
                count += 1
                stack.extend((node, c) for c in node.children.values())
        assert count == self.n_pages, (count, self.n_pages)
