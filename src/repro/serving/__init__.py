from .engine import ServeConfig, generate, make_prefill_step, make_serve_step
from .batcher import BatcherStats, ContinuousBatcher, Request
from .kv_cache import cache_len, kv_cache_bytes, seed_kv_cache, seed_ssm_state
from .tenancy import (
    CompiledProgram,
    ServingExecutor,
    TwoStageCompiler,
    VirtualAcceleratorPool,
    make_serving_hypervisor,
)

__all__ = [
    "ServeConfig", "generate", "make_prefill_step", "make_serve_step",
    "BatcherStats", "ContinuousBatcher", "Request", "cache_len",
    "kv_cache_bytes", "seed_kv_cache", "seed_ssm_state", "CompiledProgram",
    "ServingExecutor", "TwoStageCompiler", "VirtualAcceleratorPool",
    "make_serving_hypervisor",
]
