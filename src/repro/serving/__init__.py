from .engine import (
    PageState,
    ServeConfig,
    SlotState,
    admit_program,
    cached_admit_program,
    chunk_bucket,
    decode_chunk_program,
    generate,
    init_page_state,
    init_slot_state,
    make_admit_step,
    make_cached_admit_step,
    make_decode_chunk,
    make_paged_admit_step,
    make_paged_decode_chunk,
    make_prefill_step,
    make_serve_step,
    page_push_program,
    paged_admit_program,
    paged_decode_chunk_program,
)
from .batcher import BatcherStats, ContinuousBatcher, Request
from .kv_cache import (
    PagedKVPool, PageQuotaError, cache_len, kv_cache_bytes, page_bytes,
    paged_kv_cache_bytes, pages_for, seed_kv_cache, seed_ssm_state,
    tree_bytes,
)
from .prefix_cache import PrefixCache, PrefixCacheStats, PrefixNode
from .tenancy import (
    CompiledProgram,
    ServingExecutor,
    TwoStageCompiler,
    VirtualAcceleratorPool,
    make_serving_hypervisor,
)

__all__ = [
    "PageState", "ServeConfig", "SlotState", "admit_program",
    "cached_admit_program", "chunk_bucket",
    "decode_chunk_program", "generate", "init_page_state", "init_slot_state",
    "make_admit_step", "make_cached_admit_step", "make_decode_chunk",
    "make_paged_admit_step",
    "make_paged_decode_chunk", "make_prefill_step", "make_serve_step",
    "page_push_program", "paged_admit_program", "paged_decode_chunk_program",
    "BatcherStats", "ContinuousBatcher", "Request",
    "PagedKVPool", "PageQuotaError", "cache_len", "kv_cache_bytes",
    "page_bytes", "paged_kv_cache_bytes", "pages_for", "seed_kv_cache",
    "seed_ssm_state", "tree_bytes",
    "PrefixCache", "PrefixCacheStats", "PrefixNode",
    "CompiledProgram", "ServingExecutor", "TwoStageCompiler",
    "VirtualAcceleratorPool", "make_serving_hypervisor",
]
