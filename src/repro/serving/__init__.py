from .engine import (
    ServeConfig,
    SlotState,
    admit_program,
    chunk_bucket,
    decode_chunk_program,
    generate,
    init_slot_state,
    make_admit_step,
    make_decode_chunk,
    make_prefill_step,
    make_serve_step,
)
from .batcher import BatcherStats, ContinuousBatcher, Request
from .kv_cache import (
    cache_len, kv_cache_bytes, seed_kv_cache, seed_ssm_state, tree_bytes,
)
from .tenancy import (
    CompiledProgram,
    ServingExecutor,
    TwoStageCompiler,
    VirtualAcceleratorPool,
    make_serving_hypervisor,
)

__all__ = [
    "ServeConfig", "SlotState", "admit_program", "chunk_bucket",
    "decode_chunk_program", "generate", "init_slot_state",
    "make_admit_step", "make_decode_chunk", "make_prefill_step",
    "make_serve_step", "BatcherStats", "ContinuousBatcher", "Request",
    "cache_len", "kv_cache_bytes", "seed_kv_cache", "seed_ssm_state",
    "tree_bytes",
    "CompiledProgram", "ServingExecutor", "TwoStageCompiler",
    "VirtualAcceleratorPool", "make_serving_hypervisor",
]
