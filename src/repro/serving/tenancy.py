"""Tenancy: the paper's virtualization machinery driving JAX meshes.

This is the TPU-side realization of the paper's stack (DESIGN.md §2 table):

  FPGA small core           → a fixed group of TPU devices ("core")
  multi-core HRP            → :class:`VirtualAcceleratorPool` — the *same*
                              ``repro.core.hrp.ResourcePool`` bookkeeping,
                              leases mapped to disjoint device sub-meshes
  instruction frame package → an AOT-compiled XLA executable for one
                              (program × shape × lease size)
  static compilation        → :meth:`TwoStageCompiler.static_compile` —
                              offline lower+compile for every lease size the
                              pool can grant (seconds, like the paper's 14-47 s)
  dynamic compilation       → :meth:`TwoStageCompiler.reconfigure` — cache
                              lookup + context migration (milliseconds)
  layer-level ctx switch    → caches/params re-laid-out onto the new mesh
                              (device_put); decode resumes at the same token
  DDR-port budget check     → per-lease HBM admission via kv_cache_bytes

Physical isolation is inherited: leases are disjoint device sets, so one
tenant's programs literally cannot address another's HBM.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.dispatch import SwitchMode
from repro.core.events import RequestRecord
from repro.core.hrp import HRPError, Lease, ResourcePool
from repro.core.hypervisor import Hypervisor, TenantSpec
from repro.obs import Telemetry
from repro.serving.kv_cache import kv_cache_bytes, paged_kv_cache_bytes

HBM_BYTES_PER_DEVICE = 16 << 30   # TPU v5e


class VirtualAcceleratorPool:
    """Device-backed hardware resource pool (paper §4.2.2 on a TPU slice).

    ``kv_pages`` adds the memory lease dimension: a pool-wide budget of
    paged-KV cache pages the hypervisor may divide among tenants alongside
    cores (see ``repro.core.hrp.ResourcePool.set_kv_lease``).
    """

    def __init__(self, devices: Optional[Sequence] = None, *,
                 devices_per_core: int = 1, cores_per_group: int = 4,
                 kv_pages: int = 0):
        devices = list(devices if devices is not None else jax.devices())
        assert len(devices) % devices_per_core == 0
        self.devices_per_core = devices_per_core
        self.core_devices: List[List] = [
            devices[i * devices_per_core : (i + 1) * devices_per_core]
            for i in range(len(devices) // devices_per_core)
        ]
        # DDR-group budget reused as an HBM/ICI locality group
        self.pool = ResourcePool(
            n_cores=len(self.core_devices), cores_per_ddr=cores_per_group,
            ddr_port_bits=cores_per_group * 128, core_port_bits=128,
            n_kv_pages=kv_pages,
        )

    @property
    def n_cores(self) -> int:
        return self.pool.n_cores

    def lease(self, tenant: str, n_cores: int) -> Lease:
        return self.pool.alloc(tenant, n_cores)

    def resize(self, tenant: str, n_cores: int) -> Lease:
        return self.pool.resize(tenant, n_cores)

    def release(self, tenant: str) -> None:
        self.pool.release(tenant)

    def mesh_for(self, lease: Lease, *, axis_names: Tuple[str, str] = ("data", "model")) -> Mesh:
        """Disjoint sub-mesh over the leased cores: (n_cores, devices_per_core)."""
        devs = np.array(
            [self.core_devices[c] for c in lease.cores], dtype=object
        ).reshape(len(lease.cores), self.devices_per_core)
        return Mesh(devs, axis_names)

    def tp_mesh_for(self, lease: Lease) -> Mesh:
        """Flat ``("tp",)`` sub-mesh over *all* the lease's devices — the
        shape ``ContinuousBatcher`` shards its decode over.  A lease of
        ``n`` cores at ``devices_per_core`` each becomes a tensor-parallel
        width of ``n * devices_per_core``; resizing the lease re-meshes the
        tenant's batcher to the new width (``exec_resize`` → the tenant's
        registered remesh callback)."""
        devs = np.array(
            [d for c in lease.cores for d in self.core_devices[c]],
            dtype=object,
        )
        return Mesh(devs, ("tp",))

    def check_hbm(self, cfg, lease: Lease, *, batch: int, max_len: int) -> None:
        """Admission control: model + KV bytes must fit the lease's HBM
        (the paper's DDR-port-budget rule, §4.2.2)."""
        n_dev = len(lease.cores) * self.devices_per_core
        param_bytes = cfg.param_count() * 2            # bf16
        kv = kv_cache_bytes(cfg, batch, max_len)
        need = (param_bytes + kv) / n_dev
        if need > HBM_BYTES_PER_DEVICE:
            raise HRPError(
                f"lease of {n_dev} devices cannot hold {need/2**30:.1f} GiB/device "
                f"(params {param_bytes/2**30:.1f} + kv {kv/2**30:.1f} GiB)"
            )

    def check_hbm_paged(self, cfg, lease: Lease, *, n_pages: int,
                        page_size: int) -> None:
        """Paged variant of :meth:`check_hbm`: model + page-pool bytes must
        fit the lease — the pool is sized by *pages*, not slots x max_len,
        which is exactly how paging over-subscribes nominal capacity."""
        n_dev = len(lease.cores) * self.devices_per_core
        param_bytes = cfg.param_count() * 2
        kv = paged_kv_cache_bytes(cfg, n_pages, page_size)
        need = (param_bytes + kv) / n_dev
        if need > HBM_BYTES_PER_DEVICE:
            raise HRPError(
                f"lease of {n_dev} devices cannot hold {need/2**30:.1f} "
                f"GiB/device (params {param_bytes/2**30:.1f} + paged kv "
                f"{kv/2**30:.1f} GiB)"
            )


@dataclasses.dataclass
class CompiledProgram:
    executable: Any
    lowered_seconds: float
    compile_seconds: float
    n_cores: int


class TwoStageCompiler:
    """Two-stage static→dynamic compilation for serving programs.

    ``static_compile`` is the offline stage: for every lease size a tenant
    may be resized to, AOT-lower and compile the program (seconds).
    ``reconfigure`` is the online stage: resize the lease, fetch the cached
    executable, and migrate live state (params/caches) onto the new mesh —
    the measured millisecond path (Table 2 analogue;
    benchmarks/bench_compile_cache.py).
    """

    def __init__(self, pool: VirtualAcceleratorPool, *,
                 clock: Optional[Callable[[], float]] = None):
        self.pool = pool
        self._cache: Dict[Tuple, CompiledProgram] = {}
        # injectable so compile/migrate timings are deterministic in tests
        self._clock = clock if clock is not None else time.perf_counter

    # -- offline -------------------------------------------------------
    def static_compile(
        self, key: str, program: Callable, abstract_args: Tuple,
        *, lease_sizes: Sequence[int], mesh_builder: Callable[[int], Mesh],
        shardings_builder: Optional[Callable[[Mesh], Tuple]] = None,
    ) -> Dict[int, CompiledProgram]:
        """Compile ``program`` for every lease size; cache executables."""
        out = {}
        for n in lease_sizes:
            mesh = mesh_builder(n)
            in_sh = None
            if shardings_builder is not None:
                in_sh = shardings_builder(mesh)
            t0 = self._clock()
            jitted = jax.jit(program, in_shardings=in_sh) if in_sh is not None else jax.jit(program)
            with mesh:
                lowered = jitted.lower(*abstract_args)
            t1 = self._clock()
            compiled = lowered.compile()
            t2 = self._clock()
            prog = CompiledProgram(
                executable=compiled, lowered_seconds=t1 - t0,
                compile_seconds=t2 - t1, n_cores=n,
            )
            self._cache[(key, n)] = prog
            out[n] = prog
        return out

    def lookup(self, key: str, n_cores: int) -> Optional[CompiledProgram]:
        return self._cache.get((key, n_cores))

    # -- online ----------------------------------------------------------
    def reconfigure(
        self, tenant: str, key: str, n_cores: int,
        *, live_state: Any = None, state_specs: Any = None,
    ) -> Tuple[CompiledProgram, Any, Dict[str, float]]:
        """Resize ``tenant`` to ``n_cores``; return (program, migrated state,
        timing breakdown).  Raises if the static stage didn't cover
        ``n_cores`` (the paper's design rule: IFPs are pre-generated for
        every allocatable core count)."""
        t0 = self._clock()
        lease = self.pool.resize(tenant, n_cores)
        prog = self.lookup(key, n_cores)
        if prog is None:
            raise HRPError(
                f"no static artifact for ({key}, {n_cores}); "
                f"static_compile must cover all lease sizes"
            )
        t1 = self._clock()
        migrated = live_state
        if live_state is not None:
            mesh = self.pool.mesh_for(lease)
            if state_specs is not None:
                sh = jax.tree.map(
                    lambda s: NamedSharding(mesh, s), state_specs,
                    is_leaf=lambda x: isinstance(x, P),
                )
                migrated = jax.tree.map(jax.device_put, live_state, sh)
            else:
                migrated = jax.device_put(live_state, mesh.devices.flat[0])
        t2 = self._clock()
        timing = {
            "t_lookup": t1 - t0,
            "t_migrate": t2 - t1,
            "t_context": t2 - t0,
        }
        return prog, migrated, timing


class ServingExecutor:
    """Hypervisor executor for the JAX serving stack.

    This gives the serving side the *same* scheduling interface as the
    simulation engine: a :class:`repro.core.hypervisor.Hypervisor` makes the
    placement decisions (which tenant gets how many cores, who waits), and
    this adapter carries them out —

    * **admission**  → ``VirtualAcceleratorPool.lease`` + AOT-program cache
      lookup for the granted lease size,
    * **resize**     → :meth:`TwoStageCompiler.reconfigure` (cache lookup +
      live-state migration, the measured millisecond path) — so
      ``reconfigure`` is invoked by policy decisions rather than ad-hoc
      calls; tenants without a registered program key fall back to a plain
      lease resize,
    * **departure**  → lease release and per-tenant state cleanup.

    Time is real here, so ``advance`` is a no-op and the event loop serves as
    an ordered, invariant-checked decision log.  ``TenantSpec.artifact`` is
    interpreted as the tenant's program key (the ``key`` passed to
    ``static_compile``), or ``None`` for tenants managed outside the AOT
    cache (e.g. a ContinuousBatcher driving jit directly).

    **SLO enforcement on the live batcher.**  A ``latency_slo`` hypervisor
    needs ``estimate_latency(spec, n_cores)``: either register an explicit
    per-tenant model (:meth:`register_latency_model` — e.g. calibrated from
    ``bench_serving`` numbers), or feed measured per-request latencies in
    with :meth:`record_latency` / :meth:`note_completion` (the batcher owner
    calls it as requests finish) and the executor extrapolates from the
    EWMA assuming ~linear scaling over the current lease size.  Policy
    decisions then resize the batcher through ``reconfigure`` exactly like
    any other resize — cache lookup + donated-state migration.  Preemptive
    eviction (``exec_evict``) releases the lease but keeps the tenant's
    registered state/keys so a later re-admission resumes cleanly.
    """

    #: finished-request callback; a Hypervisor overwrites this at
    #: construction so completions become COMPLETION events on its timeline
    completion_sink: Optional[Callable[[RequestRecord], None]]

    def __init__(self, vpool: VirtualAcceleratorPool,
                 compiler: Optional[TwoStageCompiler] = None,
                 *, latency_ewma_alpha: float = 0.3,
                 clock: Optional[Callable[[], float]] = None,
                 telemetry: Optional[Telemetry] = None) -> None:
        self.vpool = vpool
        # injectable clock (satellite of the telemetry plane): every
        # wall-clock stamp in reconfig_log flows through it, so tracing
        # tests can pin time; the default compiler inherits the same hook
        self._clock = clock if clock is not None else time.perf_counter
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self._reg = self.telemetry.registry
        self._tracer = self.telemetry.tracer
        self.compiler = compiler if compiler is not None \
            else TwoStageCompiler(vpool, clock=clock)
        self.pool = vpool.pool                       # Hypervisor reads .pool
        self.programs: Dict[str, Optional[CompiledProgram]] = {}
        self.live_state: Dict[str, Any] = {}
        self.state_specs: Dict[str, Any] = {}
        self.reconfig_log: List[Dict[str, Any]] = []
        self._keys: Dict[str, Optional[str]] = {}
        self._on_migrate: Dict[str, Callable[[Any], None]] = {}
        self._kv_limit_cbs: Dict[str, Callable[[int], None]] = {}
        self._remesh_cbs: Dict[str, Callable[[Mesh], None]] = {}
        # fault-domain plumbing
        self._fault_sinks: Dict[str, Callable[[Any], None]] = {}
        self.fault_log: List[Dict[str, Any]] = []
        # SLO plumbing
        self.completion_sink = None
        self.pending_requests: Dict[str, List[RequestRecord]] = {}
        self._request_sinks: Dict[str, Callable[[RequestRecord], None]] = {}
        self._latency_models: Dict[str, Callable[[int], float]] = {}
        self._ewma_alpha = latency_ewma_alpha
        # tenant -> (ewma seconds, lease size the measurements came from)
        self._ewma: Dict[str, Tuple[float, int]] = {}

    def register_state(self, tenant: str, live_state: Any,
                       state_specs: Any = None,
                       on_migrate: Optional[Callable[[Any], None]] = None,
                       ) -> None:
        """Attach the tenant's live state (params/caches) so policy-driven
        resizes migrate it onto the new mesh.

        ``live_state`` may be the state pytree itself, or a zero-arg
        callable returning the *current* state.  The callable form is
        required for owners that donate their buffers every dispatch (e.g.
        ``ContinuousBatcher.live_state``): a stored pytree reference would
        be dead by the time a resize lands between chunks.  ``on_migrate``
        is invoked with the migrated tree after a resize so the owner can
        adopt it (``ContinuousBatcher.adopt_state``).  For a speculative
        batcher the tree also carries the n-gram draft state, so drafter
        history survives a policy-driven resize along with the caches."""
        self.live_state[tenant] = live_state
        if state_specs is not None:
            self.state_specs[tenant] = state_specs
        if on_migrate is not None:
            self._on_migrate[tenant] = on_migrate

    # -- SLO plumbing ---------------------------------------------------
    def register_latency_model(self, tenant: str,
                               fn: Callable[[int], float]) -> None:
        """Explicit latency model ``fn(n_cores) -> seconds`` for the
        ``latency_slo`` policy's demand computation (takes precedence over
        the measured EWMA)."""
        self._latency_models[tenant] = fn

    def register_kv_limit(self, tenant: str,
                          fn: Callable[[int], None]) -> None:
        """Where the tenant's ``kv_pages`` lease changes land — typically
        ``batcher.set_page_limit``, so a hypervisor trading memory between
        tenants throttles the live page pool mid-run."""
        self._kv_limit_cbs[tenant] = fn

    def register_remesh(self, tenant: str,
                        fn: Callable[[Mesh], None]) -> None:
        """Where the tenant's lease-driven mesh changes land — typically
        ``lambda mesh: batcher.remesh(mesh=mesh)``.  When the hypervisor
        resizes the lease, ``exec_resize`` builds the new flat ``("tp",)``
        sub-mesh over the leased devices (``tp_mesh_for``) and hands it to
        the callback, so a live ContinuousBatcher re-shards its params and
        donated caches onto the new device set mid-stream, token-identically.
        Applies to tenants managed outside the AOT cache (``artifact=None``);
        AOT tenants migrate through ``TwoStageCompiler.reconfigure``."""
        self._remesh_cbs[tenant] = fn

    def register_fault_sink(self, tenant: str,
                            fn: Callable[[Any], None]) -> None:
        """Where the tenant's ``FAILURE`` events land — e.g. a chaos driver
        forwarding a ``KV_CORRUPT`` fault to the live batcher's
        ``inject_kv_corruption`` so the audit pass has something real to
        heal.  Core faults are delivered to the failing core's lease owner;
        pool-level faults (no core) go to every sink."""
        self._fault_sinks[tenant] = fn

    def register_request_sink(self, tenant: str,
                              fn: Callable[[RequestRecord], None]) -> None:
        """Where the tenant's open-loop requests go on arrival — typically
        ``lambda rec: batcher.submit(...)``.  Without a sink, requests pile
        up in ``pending_requests`` for the owner to drain."""
        self._request_sinks[tenant] = fn

    def record_latency(self, tenant: str, seconds: float,
                       *, slo: Optional[float] = None) -> None:
        """Feed one measured request latency into the tenant's EWMA (the
        fallback demand model) and its SLO attainment counters.  The lease
        size at measurement time is stored with the EWMA so extrapolation
        stays anchored to the cores that produced the number — even after
        the lease is released (eviction, departure)."""
        lease = self.pool.lease_of(tenant)
        k_now = lease.n_cores if lease is not None else None
        prev = self._ewma.get(tenant)
        a = self._ewma_alpha
        if prev is None:
            self._ewma[tenant] = (seconds, k_now or 1)
        else:
            prev_s, prev_k = prev
            self._ewma[tenant] = (a * seconds + (1 - a) * prev_s,
                                  k_now if k_now is not None else prev_k)
        self._reg.counter("slo.requests", tenant).inc()
        if slo is not None and seconds <= slo:
            self._reg.counter("slo.met", tenant).inc()
        self._reg.histogram("slo.latency_s", tenant).record(seconds)

    def note_completion(self, record: RequestRecord) -> None:
        """Report a finished request: updates the latency EWMA/SLO counters
        and forwards the record to the hypervisor's ``completion_sink``."""
        lat = record.latency
        if lat is not None:
            self.record_latency(record.tenant, lat, slo=record.slo)
        if self.completion_sink is not None:
            self.completion_sink(record)

    def note_drop(self, record: RequestRecord) -> None:
        """Report a request shed by the drop policy (deadline passed before
        start): it counts as offered-but-unserved in :meth:`slo_report` —
        never toward the latency EWMA (it has no service time)."""
        record.dropped = True
        self._reg.counter("slo.requests", record.tenant).inc()
        self._reg.counter("slo.dropped", record.tenant).inc()

    def note_shared_kv(self, tenant: str, pages: int) -> None:
        """Report how many of ``tenant``'s kv pages currently back its
        shared prefix cache (``ContinuousBatcher.stats.shared_pages``):
        recorded on the pool (``ResourcePool.note_shared_kv``) so
        ``kv_pages_proportional`` treats the pinned set as a soft floor and
        ``check_kv_quota`` audits it each event."""
        self.pool.note_shared_kv(tenant, pages)

    def estimate_latency(self, spec: TenantSpec, n_cores: int) -> Optional[float]:
        """Demand model for ``latency_slo``: the registered model when there
        is one, else the measured EWMA extrapolated from the lease size it
        was measured at, assuming ~linear scaling (None when nothing is
        known — the policy then falls back to the tenant's floor)."""
        model = self._latency_models.get(spec.name)
        if model is not None:
            return float(model(n_cores))
        observed = self._ewma.get(spec.name)
        if observed is None:
            return None
        seconds, k0 = observed
        return seconds * k0 / max(n_cores, 1)

    @property
    def _slo_counts(self) -> Dict[str, Dict[str, int]]:
        """Legacy view of the registry-backed SLO counters (the pre-obs
        dict shape, kept so nothing downstream has to change)."""
        out: Dict[str, Dict[str, int]] = {}
        for tenant in self._reg.labels("slo.requests"):
            counts = {"n": self._reg.counter("slo.requests", tenant).value,
                      "met": self._reg.counter("slo.met", tenant).value}
            dropped = self._reg.counter("slo.dropped", tenant).value
            if dropped:
                counts["dropped"] = dropped
            out[tenant] = counts
        return out

    def slo_report(self) -> Dict[str, Dict[str, Any]]:
        """Per-tenant SLO attainment over everything fed through
        :meth:`record_latency` / :meth:`note_completion`.  Percentile
        latencies (p50/p95/p99, seconds) come from the registry's
        log-bucketed latency histogram — ``None`` for a tenant with no
        served requests (e.g. all dropped)."""
        out: Dict[str, Dict[str, Any]] = {}
        for tenant in self._reg.labels("slo.requests"):
            n = self._reg.counter("slo.requests", tenant).value
            met = self._reg.counter("slo.met", tenant).value
            ewma = self._ewma.get(tenant)
            hist = self._reg.histogram("slo.latency_s", tenant)
            out[tenant] = {
                "requests": n,
                "slo_met": met,
                "dropped": self._reg.counter("slo.dropped", tenant).value,
                "attainment": met / n if n else None,
                "ewma_latency": ewma[0] if ewma is not None else None,
                "p50_latency": hist.quantile(0.50) if hist.count else None,
                "p95_latency": hist.quantile(0.95) if hist.count else None,
                "p99_latency": hist.quantile(0.99) if hist.count else None,
            }
        return out

    def program_of(self, tenant: str) -> Optional[CompiledProgram]:
        return self.programs.get(tenant)

    def mesh_of(self, tenant: str) -> Mesh:
        lease = self.pool.lease_of(tenant)
        if lease is None:
            raise HRPError(f"tenant {tenant} holds no lease")
        return self.vpool.mesh_for(lease)

    # -- hypervisor executor protocol ----------------------------------
    def begin(self, horizon: float) -> None:
        pass

    def advance(self, until: float) -> None:
        pass  # real time: nothing to simulate between events

    def probe(self, at: float) -> int:
        return 0

    def metrics(self) -> Dict[str, Any]:
        return {"reconfigs": list(self.reconfig_log),
                "allocation": {t: l.n_cores for t, l in self.pool.leases.items()}}

    def exec_admit(self, spec: TenantSpec, n_cores: int, at: float) -> None:
        self.vpool.lease(spec.name, n_cores)
        key = spec.artifact if isinstance(spec.artifact, str) else None
        self._keys[spec.name] = key
        self.programs[spec.name] = (
            self.compiler.lookup(key, n_cores) if key is not None else None
        )

    def exec_resize(self, name: str, n_cores: int, at: float,
                    mode: SwitchMode) -> None:
        lease = self.pool.lease_of(name)
        if lease is not None and lease.n_cores == n_cores:
            return
        key = self._keys.get(name)
        if key is None:
            new_lease = self.vpool.resize(name, n_cores)
            entry = {"tenant": name, "n_cores": n_cores}
            cb = self._remesh_cbs.get(name)
            if cb is not None:
                t0 = self._clock()
                cb(self.vpool.tp_mesh_for(new_lease))
                entry["t_remesh"] = self._clock() - t0
                self._tracer.complete("remesh", name, t0,
                                      entry["t_remesh"],
                                      {"n_cores": n_cores})
            self.reconfig_log.append(entry)
            return
        state = self.live_state.get(name)
        pulled = callable(state)
        if pulled:
            state = state()                  # pull the owner's CURRENT tree
        t0 = self._clock()
        prog, migrated, timing = self.compiler.reconfigure(
            name, key, n_cores,
            live_state=state,
            state_specs=self.state_specs.get(name),
        )
        self._tracer.complete("reconfigure", name, t0,
                              self._clock() - t0, {"n_cores": n_cores})
        self.programs[name] = prog
        if name in self.live_state and not pulled:
            self.live_state[name] = migrated
        cb = self._on_migrate.get(name)
        if cb is not None and migrated is not None:
            cb(migrated)
        self.reconfig_log.append({"tenant": name, "n_cores": n_cores, **timing})

    def exec_kv_resize(self, name: str, kv_pages: int, at: float) -> None:
        """Apply a kv-page lease change: forward the new cap to the tenant's
        registered page-limit callback (``ContinuousBatcher.set_page_limit``)
        and log it next to core reconfigs."""
        cb = self._kv_limit_cbs.get(name)
        if cb is not None:
            cb(kv_pages)
        self._tracer.instant("kv_resize", name, args={"kv_pages": kv_pages})
        self.reconfig_log.append({"tenant": name, "kv_pages": kv_pages})

    def exec_remove(self, name: str, at: float) -> None:
        self.vpool.release(name)
        for table in (self.programs, self.live_state, self.state_specs,
                      self._keys, self._on_migrate, self._request_sinks,
                      self.pending_requests, self._latency_models,
                      self._kv_limit_cbs, self._fault_sinks,
                      self._remesh_cbs):
            table.pop(name, None)

    def exec_request(self, name: str, record: RequestRecord, at: float) -> None:
        # drop policy at the delivery point: a request whose deadline
        # already passed before it could even reach the tenant's batcher is
        # shed here (counted in slo_report), not handed to a sink that
        # would serve it hopelessly late
        if record.deadline is not None and at > record.deadline:
            self.note_drop(record)
            return
        sink = self._request_sinks.get(name)
        if sink is not None:
            sink(record)
        else:
            self.pending_requests.setdefault(name, []).append(record)

    def exec_fault(self, fault: Any, at: float) -> None:
        """A ``FAILURE`` event fired: log it and deliver it to the affected
        tenant's fault sink.  Core death itself needs no serving-side work —
        the hypervisor displaces the owner through the normal
        ``exec_evict`` → re-admit path, and physical isolation means no
        other tenant's programs ever touched the failed core."""
        self.fault_log.append({"at": at, "fault": fault, "recovered": False})
        if fault.core is not None:
            owner = self.pool.owner_of(fault.core)
            sinks = ([self._fault_sinks[owner]]
                     if owner in self._fault_sinks else [])
        else:
            sinks = list(self._fault_sinks.values())
        for sink in sinks:
            sink(fault)

    def exec_recover(self, fault: Any, at: float) -> None:
        self.fault_log.append({"at": at, "fault": fault, "recovered": True})

    def exec_evict(self, name: str, at: float) -> None:
        """Preemptive eviction: release the lease and current program but —
        unlike :meth:`exec_remove` — keep the tenant's registered state,
        program key, sinks and latency model, so a later re-admission
        resumes where the eviction cut it off."""
        self.vpool.release(name)
        self.programs.pop(name, None)
        self._tracer.instant("evict", name)
        self.reconfig_log.append({"tenant": name, "evicted": True})


def make_serving_hypervisor(
    vpool: VirtualAcceleratorPool,
    *,
    compiler: Optional[TwoStageCompiler] = None,
    policy: Any = "even_split",
    clock: Optional[Callable[[], float]] = None,
    telemetry: Optional[Telemetry] = None,
    **kwargs: Any,
) -> Tuple[Hypervisor, ServingExecutor]:
    """One-call wiring of pool + two-stage compiler + hypervisor: returns the
    (hypervisor, executor) pair the serving stack schedules through.  A
    ``telemetry`` bundle is shared by both halves, so hypervisor events and
    executor reconfigs land in one registry and one trace timeline."""
    executor = ServingExecutor(vpool, compiler, clock=clock,
                               telemetry=telemetry)
    return Hypervisor(vpool.pool, policy=policy, executor=executor,
                      telemetry=executor.telemetry, **kwargs), executor
