"""KV-cache and SSM-state containers for serving.

The per-layer view types live next to their math (`models.attention.KVCacheView`,
`models.ssm.SSMState`); this module owns cache *lifecycle*: allocation,
seeding from prefill outputs (including ring-buffer placement for
sliding-window archs), and the byte accounting the tenancy layer uses for
admission control.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import KVCacheView
from repro.models.ssm import SSMState


def cache_len(cfg, max_len: int) -> int:
    return min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len


def seed_kv_cache(cfg, k, v, *, max_len: int, seq_positions=None) -> KVCacheView:
    """Seed a decode cache from prefill K/V.

    k, v: (nb, B, S, Hkv, dh) — stacked over blocks (scan ys).
    Ring-buffer placement: absolute position s lands in slot s % C, so decode
    can continue writing at cur_pos % C without any copy.  Only the last C
    positions are kept (for sliding-window archs C = window; older K/V is
    dead weight by definition of the mask).
    """
    nb, B, S, Hkv, dh = k.shape
    C = cache_len(cfg, max_len)
    keep = min(S, C)
    pos = np.arange(S - keep, S)                  # absolute positions kept
    slots = pos % C                               # ring slots (identity if S<=C)
    kk = k[:, :, S - keep :, :, :]
    vv = v[:, :, S - keep :, :, :]
    ck = jnp.zeros((nb, B, C, Hkv, dh), dtype=k.dtype)
    cv = jnp.zeros((nb, B, C, Hkv, dh), dtype=v.dtype)
    cpos = jnp.full((nb, B, C), -1, dtype=jnp.int32)
    slots_j = jnp.asarray(slots)
    ck = ck.at[:, :, slots_j].set(kk)
    cv = cv.at[:, :, slots_j].set(vv)
    cpos = cpos.at[:, :, slots_j].set(jnp.asarray(pos, dtype=jnp.int32))
    return KVCacheView(k=ck, v=cv, pos=cpos)


def seed_ssm_state(state: SSMState) -> SSMState:
    """Prefill already produces the exact decode state; pass through (the
    hook exists so quantized-state serving can intercept here)."""
    return state


def tree_bytes(tree) -> int:
    """Resident bytes of a cache pytree (the quantity donation keeps from
    being re-copied every decode step; reported as BatcherStats.cache_bytes)."""
    return sum(int(x.nbytes) for x in jax.tree.leaves(tree))


def kv_cache_bytes(cfg, batch: int, max_len: int) -> int:
    """HBM bytes of the full decode cache for admission control."""
    from repro.models.transformer import n_blocks, period_structure

    specs = period_structure(cfg)
    nb = n_blocks(cfg)
    C = cache_len(cfg, max_len)
    dt = jnp.dtype(cfg.dtype).itemsize
    total = 0
    for spec in specs:
        if spec.mixer == "attn":
            total += nb * batch * C * cfg.n_kv_heads * cfg.d_head * 2 * dt
            total += nb * batch * C * 4                     # pos int32
        else:
            s = cfg.ssm
            d_in = s.d_inner(cfg.d_model)
            nh = s.n_ssm_heads(cfg.d_model)
            d_bc = 2 * s.n_groups * s.d_state
            total += nb * batch * (s.d_conv - 1) * (d_in + d_bc) * dt
            total += nb * batch * nh * s.head_dim * s.d_state * 4   # f32
    if cfg.family == "audio":
        total += cfg.n_layers * batch * cfg.enc_seq * cfg.kv_dim * 2 * dt
    return total
