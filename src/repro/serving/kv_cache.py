"""KV-cache and SSM-state containers for serving.

The per-layer view types live next to their math (`models.attention.KVCacheView`,
`models.ssm.SSMState`); this module owns cache *lifecycle*: allocation,
seeding from prefill outputs (including ring-buffer placement for
sliding-window archs), and the byte accounting the tenancy layer uses for
admission control.
"""

from __future__ import annotations

from typing import Dict, Hashable, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import KVCacheView
from repro.models.ssm import SSMState


def cache_len(cfg, max_len: int) -> int:
    return min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len


def seed_kv_cache(cfg, k, v, *, max_len: int, seq_positions=None) -> KVCacheView:
    """Seed a decode cache from prefill K/V.

    k, v: (nb, B, S, Hkv, dh) — stacked over blocks (scan ys).
    Ring-buffer placement: absolute position s lands in slot s % C, so decode
    can continue writing at cur_pos % C without any copy.  Only the last C
    positions are kept (for sliding-window archs C = window; older K/V is
    dead weight by definition of the mask).
    """
    nb, B, S, Hkv, dh = k.shape
    C = cache_len(cfg, max_len)
    keep = min(S, C)
    pos = np.arange(S - keep, S)                  # absolute positions kept
    slots = pos % C                               # ring slots (identity if S<=C)
    kk = k[:, :, S - keep :, :, :]
    vv = v[:, :, S - keep :, :, :]
    ck = jnp.zeros((nb, B, C, Hkv, dh), dtype=k.dtype)
    cv = jnp.zeros((nb, B, C, Hkv, dh), dtype=v.dtype)
    cpos = jnp.full((nb, B, C), -1, dtype=jnp.int32)
    slots_j = jnp.asarray(slots)
    ck = ck.at[:, :, slots_j].set(kk)
    cv = cv.at[:, :, slots_j].set(vv)
    cpos = cpos.at[:, :, slots_j].set(jnp.asarray(pos, dtype=jnp.int32))
    return KVCacheView(k=ck, v=cv, pos=cpos)


def seed_ssm_state(state: SSMState) -> SSMState:
    """Prefill already produces the exact decode state; pass through (the
    hook exists so quantized-state serving can intercept here)."""
    return state


def tree_bytes(tree) -> int:
    """Resident bytes of a cache pytree (the quantity donation keeps from
    being re-copied every decode step; reported as BatcherStats.cache_bytes)."""
    return sum(int(x.nbytes) for x in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# Paged KV pool — host-side allocator / quota ledger
# ---------------------------------------------------------------------------


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` KV entries."""
    return -(-max(int(n_tokens), 0) // max(int(page_size), 1))


class PageQuotaError(RuntimeError):
    """A page allocation would exceed the pool or an owner's quota."""


class PagedKVPool:
    """Host-side ledger for one tenant-visible pool of fixed-size KV pages.

    The *device* owns the authoritative free stack and page tables (see
    ``serving.engine.PageState`` — allocation happens inside the jitted
    chunk/admit programs); this class is the admission-control mirror: it
    tracks how many pages each owner (a request, a slot, a tenant…) has
    reserved, enforces per-owner quotas and the pool bound, and does the
    byte accounting the tenancy layer leases against.  It deliberately
    deals in *counts*, not page ids — ids are device state.

    Conservation invariant (checked by :meth:`check`): the sum of all
    owners' reservations never exceeds ``n_pages``, and no owner exceeds
    its quota.  Over-subscription is expressed through quotas: the sum of
    quotas may exceed the pool (that is the point of paging) — the pool
    bound is enforced on actual reservations.

    **Shared pages** (the prefix cache, ``serving.prefix_cache``) are the
    one place the ledger tracks *ids*, not counts: a page whose contents are
    reusable across requests is moved out of its admitting owner's count
    (:meth:`share`) into a per-namespace shared set with a per-page
    **refcount** of active users (:meth:`acquire`/:meth:`release`).  A
    shared page is recyclable only at ``refcount == 0`` and only through an
    explicit cache eviction (:meth:`drop_shared`) — until then it stays off
    the free side of the conservation equation:

        free + Σ owner counts (private) + #shared == n_pages
    """

    def __init__(self, n_pages: int, page_size: int) -> None:
        if n_pages < 1 or page_size < 1:
            raise ValueError("n_pages and page_size must be >= 1")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self._held: Dict[Hashable, int] = {}
        self._quota: Dict[Hashable, int] = {}
        self._shared: Dict[int, Hashable] = {}   # page id -> owning namespace
        self._ref: Dict[int, int] = {}           # page id -> active users

    # -- queries --------------------------------------------------------
    @property
    def used(self) -> int:
        return sum(self._held.values()) + len(self._shared)

    @property
    def available(self) -> int:
        return self.n_pages - self.used

    def held_by(self, owner: Hashable) -> int:
        return self._held.get(owner, 0)

    def quota_of(self, owner: Hashable) -> int:
        return self._quota.get(owner, self.n_pages)

    def can_alloc(self, owner: Hashable, n: int) -> bool:
        return (n <= self.available
                and self.held_by(owner) + n <= self.quota_of(owner))

    # -- lifecycle ------------------------------------------------------
    def set_quota(self, owner: Hashable, quota: Optional[int]) -> None:
        """Cap ``owner``'s reservation; ``None`` removes the cap.  A quota
        below the owner's current holding is allowed (it only blocks further
        growth — the hypervisor shrinks leases the same way)."""
        if quota is None:
            self._quota.pop(owner, None)
        else:
            self._quota[owner] = max(int(quota), 0)

    def alloc(self, owner: Hashable, n: int) -> int:
        """Reserve ``n`` pages for ``owner``; returns the owner's new total.
        Raises :class:`PageQuotaError` when the pool or quota is exceeded."""
        if n < 0:
            raise ValueError("cannot alloc a negative page count")
        if n > self.available:
            raise PageQuotaError(
                f"want {n} pages, only {self.available}/{self.n_pages} free")
        held = self.held_by(owner) + n
        if held > self.quota_of(owner):
            raise PageQuotaError(
                f"owner {owner!r} would hold {held} pages "
                f"(quota {self.quota_of(owner)})")
        self._held[owner] = held
        return held

    def free(self, owner: Hashable, n: Optional[int] = None) -> int:
        """Return ``n`` pages (default: all) from ``owner``; returns how many
        were actually freed."""
        held = self.held_by(owner)
        n = held if n is None else min(int(n), held)
        if n < 0:
            raise ValueError("cannot free a negative page count")
        left = held - n
        if left:
            self._held[owner] = left
        else:
            self._held.pop(owner, None)
        return n

    # -- shared pages (prefix cache) ------------------------------------
    @property
    def shared(self) -> int:
        """Pages currently owned by prefix-cache namespaces."""
        return len(self._shared)

    def shared_by(self, namespace: Hashable) -> int:
        return sum(1 for ns in self._shared.values() if ns == namespace)

    def shared_ids(self) -> set:
        """Ids of all cache-owned pages — legitimately multi-mapped
        (read-only), so the batcher's page-table audit exempts them from
        double-mapping detection."""
        return set(self._shared)

    def pinned_shared(self) -> int:
        """Shared pages with at least one active user — the set a lease
        shrink cannot reclaim without faulting a live request."""
        return sum(1 for pid, rc in self._ref.items() if rc > 0)

    def refcount(self, page_id: int) -> int:
        return self._ref.get(int(page_id), 0)

    def share(self, owner: Hashable, namespace: Hashable,
              page_ids: Iterable[int]) -> None:
        """Move pages out of ``owner``'s private count into ``namespace``'s
        shared set (billed once to the namespace, refcount 0 — callers
        :meth:`acquire` separately for each active user)."""
        pids = [int(p) for p in page_ids]
        if not pids:
            return
        held = self.held_by(owner)
        if len(pids) > held:
            raise PageQuotaError(
                f"owner {owner!r} shares {len(pids)} pages but holds {held}")
        for pid in pids:
            if pid in self._shared:
                raise PageQuotaError(f"page {pid} is already shared")
            if not (0 <= pid < self.n_pages):
                raise PageQuotaError(f"page id {pid} outside the pool")
            self._shared[pid] = namespace
            self._ref[pid] = 0
        self.free(owner, len(pids))

    def acquire(self, page_ids: Iterable[int]) -> None:
        """Register one more active user on each shared page."""
        for pid in page_ids:
            pid = int(pid)
            if pid not in self._shared:
                raise PageQuotaError(f"acquire of unshared page {pid}")
            self._ref[pid] += 1

    def release(self, page_ids: Iterable[int]) -> None:
        """Drop one active user from each shared page.  A page that reaches
        refcount 0 stays shared (its contents are the cache's value) until
        an eviction calls :meth:`drop_shared`."""
        for pid in page_ids:
            pid = int(pid)
            if self._ref.get(pid, 0) < 1:
                raise PageQuotaError(f"release of page {pid} without users")
            self._ref[pid] -= 1

    def drop_shared(self, page_ids: Iterable[int]) -> int:
        """Evict pages from the shared set (cache eviction); they become
        free.  Only refcount-0 pages may be dropped; returns how many were."""
        pids = [int(p) for p in page_ids]
        for pid in pids:
            if pid not in self._shared:
                raise PageQuotaError(f"drop of unshared page {pid}")
            if self._ref.get(pid, 0) != 0:
                raise PageQuotaError(
                    f"page {pid} evicted with {self._ref[pid]} active users")
        for pid in pids:
            del self._shared[pid]
            del self._ref[pid]
        return len(pids)

    def check(self) -> None:
        """Conservation + quota invariants; raises :class:`PageQuotaError`."""
        if self.used > self.n_pages:
            raise PageQuotaError(
                f"pool oversubscribed: {self.used} > {self.n_pages}")
        for owner, held in self._held.items():
            if held < 0:
                raise PageQuotaError(f"owner {owner!r} holds {held} pages")
            if held > self.quota_of(owner):
                raise PageQuotaError(
                    f"owner {owner!r} holds {held} > quota "
                    f"{self.quota_of(owner)}")
        if set(self._ref) != set(self._shared):
            raise PageQuotaError("refcount table drifted from the shared set")
        for pid, rc in self._ref.items():
            if rc < 0:
                raise PageQuotaError(f"shared page {pid} has refcount {rc}")
            if not (0 <= pid < self.n_pages):
                raise PageQuotaError(f"shared page id {pid} outside the pool")

    def page_bytes(self, cfg) -> int:
        return page_bytes(cfg, self.page_size)

    def pool_bytes(self, cfg) -> int:
        return paged_kv_cache_bytes(cfg, self.n_pages, self.page_size)


def page_bytes(cfg, page_size: int) -> int:
    """HBM bytes of ONE pool page summed over every attention layer (the
    granularity the hypervisor's ``kv_pages`` lease dimension trades in)."""
    from repro.models.transformer import n_blocks, period_structure

    specs = period_structure(cfg)
    nb = n_blocks(cfg)
    dt = jnp.dtype(cfg.dtype).itemsize
    n_attn = sum(1 for s in specs if s.mixer == "attn")
    return n_attn * nb * page_size * cfg.n_kv_heads * cfg.d_head * 2 * dt


def paged_kv_cache_bytes(cfg, n_pages: int, page_size: int) -> int:
    """HBM bytes of the full paged pool (incl. the trash page) — the paged
    analogue of :func:`kv_cache_bytes` for admission control."""
    return (n_pages + 1) * page_bytes(cfg, page_size)


def kv_cache_bytes(cfg, batch: int, max_len: int) -> int:
    """HBM bytes of the full decode cache for admission control."""
    from repro.models.transformer import n_blocks, period_structure

    specs = period_structure(cfg)
    nb = n_blocks(cfg)
    C = cache_len(cfg, max_len)
    dt = jnp.dtype(cfg.dtype).itemsize
    total = 0
    for spec in specs:
        if spec.mixer == "attn":
            total += nb * batch * C * cfg.n_kv_heads * cfg.d_head * 2 * dt
            total += nb * batch * C * 4                     # pos int32
        else:
            s = cfg.ssm
            d_in = s.d_inner(cfg.d_model)
            nh = s.n_ssm_heads(cfg.d_model)
            d_bc = 2 * s.n_groups * s.d_state
            total += nb * batch * (s.d_conv - 1) * (d_in + d_bc) * dt
            total += nb * batch * nh * s.head_dim * s.d_state * 4   # f32
    if cfg.family == "audio":
        total += cfg.n_layers * batch * cfg.enc_seq * cfg.kv_dim * 2 * dt
    return total
