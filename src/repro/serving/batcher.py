"""Continuous batching over fixed decode slots — chunked, donated hot path.

The decode program has a fixed batch shape (XLA requirement); the batcher
multiplexes a dynamic request stream onto B fixed slots:

* new requests are prefilled **right-sized** (the joining rows only,
  bucketed to powers of two so the jit cache stays small) and their caches
  scattered into free slots with per-slot ``.at[:, slot].set`` writes — one
  fused admission dispatch, no full-tree ``jnp.where`` merge;
* decode runs in **chunks**: one ``lax.scan`` program advances all slots T
  steps with EOS/max-token detection on device, so the host pays one
  dispatch and one blocking sync per T tokens instead of per token.  T
  adapts to queue pressure (short chunks while requests wait, long chunks
  when the queue is dry) over the same power-of-two buckets;
* cache and slot-state buffers are **donated** into both programs
  (``jax.jit(..., donate_argnums=...)``), so XLA updates the ring-buffer KV
  in place — without donation every token copies the entire cache tree;
* slots free on EOS/max-tokens and are immediately refillable — the
  dynamic-workload serving pattern of the paper's private-cloud scenario,
  with the slot pool playing the role of the core pool at request
  granularity.

Invariants:

* ``self.caches``/``self.state`` always refer to the *latest* donated
  outputs; any previously exported reference is dead.  External consumers
  (e.g. ``ServingExecutor.register_state`` for mid-run resizes) must pull
  through :meth:`live_state` and hand back migrated trees via
  :meth:`adopt_state` — never hold the raw arrays across a step.
* ``slot_req[i] is not None`` ⟺ slot i is active on device; the host mirror
  is reconciled from the fetched ``emitted`` mask after every chunk.
* A slot that finishes mid-chunk keeps decoding with its position frozen,
  overwriting only its own ring slot; admission re-seeds the cache before
  reuse (see ``serving.engine``).

Host-side bookkeeping is numpy; device work happens only in the two jitted
programs.

**Paged mode** (``paged=True``): the per-slot dense ring buffers are replaced
by one pre-allocated pool of fixed-size KV pages (the cache analogue of the
paper's instruction-frame tile) with per-slot page tables — see
``serving.engine.PageState``.  Admission is gated on *page availability*
instead of slot count alone: each joining request reserves its worst-case
footprint (``ceil((prompt_len + max_new)/page_size)`` pages, or just the
prompt pages with ``reserve_pages=False``) in a host-side
:class:`~repro.serving.kv_cache.PagedKVPool` ledger, so the pool can hold
far more slots than dense rings of the same HBM would (slots whose actual
use is below ``max_len`` stop paying for it).  Page faults during decode are
handled on device inside the chunk scan; a slot denied a page (pool dry or
``kv_pages`` quota hit — only possible without reservations) deactivates,
and the host requeues its request at the queue head
(``stats.oom_requeues``).  The single post-chunk sync additionally carries
``active`` and ``free_top`` so the host ledger stays reconciled.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Caches, init_caches, init_paged_caches
from .kv_cache import PagedKVPool, pages_for, tree_bytes
from .engine import (
    PageState,
    ServeConfig,
    SlotState,
    admit_program,
    chunk_bucket,
    decode_chunk_program,
    init_page_state,
    init_slot_state,
    paged_admit_program,
    paged_decode_chunk_program,
)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int
    eos: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class BatcherStats:
    steps: int = 0               # device decode steps executed (Σ chunk T)
    chunks: int = 0              # decode_chunk dispatches
    prefills: int = 0            # admission dispatches
    completed: int = 0
    slot_busy_steps: int = 0
    slot_total_steps: int = 0
    dispatches: int = 0          # all jitted dispatches (admit + chunk)
    host_syncs: int = 0          # blocking device→host fetches
    decode_tokens: int = 0       # tokens emitted by decode chunks
    admit_tokens: int = 0        # first tokens emitted at admission
    cache_bytes: int = 0         # resident cache-tree size (donated in place)
    admit_scatter_bytes: int = 0  # bytes scattered at admission (vs. full-tree)
    # paged mode
    oom_requeues: int = 0        # requests requeued after a denied page fault
    oom_discarded_tokens: int = 0  # emitted tokens thrown away by requeues
    pages_in_use: int = 0        # device-allocated pages after the last sync
    peak_pages_in_use: int = 0
    peak_resident: int = 0       # most simultaneously-resident requests

    @property
    def occupancy(self) -> float:
        return self.slot_busy_steps / max(self.slot_total_steps, 1)

    @property
    def tokens(self) -> int:
        """Tokens actually *delivered*: a restarted (OOM-requeued) request's
        discarded emissions were device work but not throughput — without
        the correction, over-subscribed tokens/s would be inflated by
        exactly the thrashing the residency throttle exists to limit."""
        return self.decode_tokens + self.admit_tokens \
            - self.oom_discarded_tokens

    @property
    def dispatches_per_token(self) -> float:
        return self.dispatches / max(self.tokens, 1)

    @property
    def syncs_per_token(self) -> float:
        return self.host_syncs / max(self.tokens, 1)

    @property
    def decode_dispatches_per_token(self) -> float:
        """Dispatches on the pure-decode path: 1/T when chunks run full."""
        return self.chunks / max(self.decode_tokens, 1)


class ContinuousBatcher:
    """Fixed-slot continuous batcher for one tenant's model."""

    def __init__(self, params, cfg, *, slots: int, prompt_len: int,
                 max_len: int, policy=None, attn_impl: str = "xla",
                 chunk: int = 8, paged: bool = False, page_size: int = 16,
                 n_pages: Optional[int] = None,
                 page_quota: Optional[int] = None,
                 reserve_pages: bool = True):
        self.params = params
        self.cfg = cfg
        self.B = slots
        self.prompt_len = prompt_len
        self.chunk = max(1, chunk)
        scfg = ServeConfig(max_len=max_len, attn_impl=attn_impl,
                           chunk=self.chunk)
        self.scfg = scfg
        self._policy = policy
        self.paged = paged
        self.queue: Deque[Request] = deque()
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.state: SlotState = init_slot_state(slots)
        if paged:
            self.page_size = max(1, page_size)
            self.max_pages = pages_for(max_len, self.page_size)
            # default pool == dense capacity; pass a smaller n_pages to
            # over-subscribe (the bench's equal-HBM capacity argument)
            self.n_pages = n_pages if n_pages is not None \
                else slots * self.max_pages
            self.reserve_pages = reserve_pages
            self._page_limit = min(page_quota, self.n_pages) \
                if page_quota is not None else self.n_pages
            self.kv_pool = PagedKVPool(self.n_pages, self.page_size)
            self.caches: Caches = init_paged_caches(
                cfg, slots, self.n_pages, self.page_size)
            if not self.caches.kv:
                raise ValueError("paged mode needs at least one attn layer")
            self.pages: Optional[PageState] = init_page_state(
                slots, self.n_pages, self.max_pages, quota=self._page_limit)
            self._admit_fn = paged_admit_program(cfg, scfg, policy=policy)
        else:
            self.caches = init_caches(cfg, slots, max_len)
            self.pages = None
            self._admit_fn = admit_program(cfg, scfg, policy=policy)
        self.stats = BatcherStats(cache_bytes=tree_bytes(self.caches))
        self._key = jax.random.PRNGKey(0)
        self._stalled = 0           # consecutive zero-emission paged chunks
        self._admitted_pages_since_sync = 0
        # over-subscription throttle: after a denied page fault, cap
        # residency at the survivors so restarted requests stop thrashing
        # the ones still making progress; recover one slot per clean round
        self._resident_cap = slots

    # -- request intake ------------------------------------------------
    def submit(self, req: Request) -> None:
        assert req.prompt.shape[0] <= self.prompt_len
        if self.paged:
            assert self._request_pages(req) <= self.n_pages, \
                "request footprint exceeds the whole page pool"
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    # -- paged-mode ledger ----------------------------------------------
    def _request_pages(self, req: Request) -> int:
        """Ledger reservation for one request: its worst-case footprint
        (bucketed prompt + full decode budget) when reserving, prompt pages
        only when running over-subscribed."""
        toks = self.prompt_len + (req.max_new if self.reserve_pages else 0)
        return pages_for(toks, self.page_size)

    def set_page_limit(self, n_pages: int) -> None:
        """Adjust the tenant's ``kv_pages`` lease cap mid-run (hypervisor
        kv resize).  Takes effect on the next dispatch; shrinking below the
        current allocation only blocks further growth — resident pages
        drain as their slots complete."""
        assert self.paged, "page limits only apply to paged batchers"
        self._page_limit = max(0, min(int(n_pages), self.n_pages))
        self.pages = self.pages._replace(quota=jnp.int32(self._page_limit))

    def _pages_available(self, need: int) -> bool:
        if self.kv_pool.used + need > self._page_limit:
            return False
        avail = self.kv_pool.available
        if not self.reserve_pages:
            # the ledger only reserved prompt pages; residents' decode pages
            # live on device.  Bound admission by the device allocation seen
            # at the last sync (plus prompts admitted since), and keep one
            # page of headroom whenever someone is already resident so at
            # least one slot can take the decode-time fault and progress.
            device_avail = (self.n_pages - self.stats.pages_in_use
                            - self._admitted_pages_since_sync)
            avail = min(avail, device_avail)
            need += int(any(r is not None for r in self.slot_req))
        return need <= avail

    # -- mid-run migration (Hypervisor resize between chunks) -----------
    def live_state(self) -> Dict[str, Any]:
        """Current device state, for ``TwoStageCompiler.reconfigure``
        migration.  Pull-only: the returned arrays are donated (dead) after
        the next step — register this *method* (not its result) with
        ``ServingExecutor.register_state``.  Paged batchers also carry the
        page tables / free stack, so a resize migrates the whole pool."""
        out = {"caches": self.caches, "slots": self.state}
        if self.paged:
            out["pages"] = self.pages
        return out

    def adopt_state(self, state: Dict[str, Any]) -> None:
        """Adopt a migrated state tree; decode resumes at the same token."""
        self.caches = state["caches"]
        self.state = state["slots"]
        if self.paged:
            self.pages = state["pages"]

    # -- admission: right-sized prefill + per-slot scatter ---------------
    def _admit(self) -> None:
        free = self._free_slots()
        if not free or not self.queue:
            return
        joins = []
        resident = sum(r is not None for r in self.slot_req)
        while free and self.queue:
            if self.paged:
                if resident + len(joins) >= self._resident_cap:
                    break
                # admission by page availability: the queue head joins only
                # when its ledger reservation fits the pool AND the lease
                # cap (head-of-line — a later smaller request never jumps)
                need = self._request_pages(self.queue[0])
                if not self._pages_available(need):
                    break
                self.kv_pool.alloc(self.queue[0].rid, need)
                self._admitted_pages_since_sync += pages_for(
                    self.prompt_len, self.page_size)
            joins.append((free.pop(0), self.queue.popleft()))
        if not joins:
            return
        n = len(joins)
        nb = min(1 << (n - 1).bit_length() if n > 1 else 1, self.B)
        toks = np.zeros((nb, self.prompt_len), dtype=np.int32)
        slots = np.zeros((nb,), dtype=np.int32)
        budget = np.zeros((nb,), dtype=np.int32)
        eos = np.full((nb,), -1, dtype=np.int32)
        for j, (slot, req) in enumerate(joins):
            p = req.prompt
            toks[j, self.prompt_len - len(p):] = p   # left-pad with 0s
            slots[j] = slot
            budget[j] = req.max_new
            if req.eos is not None:
                eos[j] = req.eos
        # pad a partial bucket by repeating row 0: duplicate-index scatters
        # then write identical values, which is deterministic
        for j in range(n, nb):
            toks[j] = toks[0]
            slots[j] = slots[0]
            budget[j] = budget[0]
            eos[j] = eos[0]
        pos0 = np.full((nb,), self.prompt_len, dtype=np.int32)
        if self.paged:
            real = np.zeros((nb,), dtype=bool)
            real[:n] = True
            nxt, self.caches, self.state, self.pages = self._admit_fn(
                self.params, {"tokens": jnp.asarray(toks)}, self.caches,
                self.state, self.pages, jnp.asarray(slots),
                jnp.asarray(pos0), jnp.asarray(budget), jnp.asarray(eos),
                jnp.asarray(real),
            )
        else:
            nxt, self.caches, self.state = self._admit_fn(
                self.params, {"tokens": jnp.asarray(toks)}, self.caches,
                self.state, jnp.asarray(slots), jnp.asarray(pos0),
                jnp.asarray(budget), jnp.asarray(eos),
            )
        self.stats.prefills += 1
        self.stats.dispatches += 1
        self.stats.admit_scatter_bytes += int(
            self.stats.cache_bytes * nb / max(self.B, 1)
        )
        nxt_np = np.asarray(nxt)
        self.stats.host_syncs += 1
        for j, (slot, req) in enumerate(joins):
            tok = int(nxt_np[j])
            req.out.append(tok)
            self.stats.admit_tokens += 1
            hit_eos = req.eos is not None and tok == req.eos
            if len(req.out) >= req.max_new or hit_eos:
                req.done = True
                self.stats.completed += 1
                if self.paged:
                    self.kv_pool.free(req.rid)
                    # done at admission: the device never popped its prompt
                    # pages (a non-activating row allocates nothing), so
                    # take it back out of the since-sync estimate — else
                    # admit-only rounds leak the counter and starve
                    # over-subscribed admission with the pool entirely free
                    self._admitted_pages_since_sync -= pages_for(
                        self.prompt_len, self.page_size)
            else:
                self.slot_req[slot] = req
        if self.paged:
            self.stats.peak_resident = max(
                self.stats.peak_resident,
                sum(r is not None for r in self.slot_req))

    # -- chunk sizing: adaptive to queue pressure ------------------------
    def _pick_chunk(self, active: List[int]) -> int:
        """Queue pressure → short chunks (the earliest completion bounds
        admission latency); dry queue → chunks up to the longest remaining
        budget.  Sizes snap to power-of-two buckets (bounded jit cache)."""
        rem = [self.slot_req[i].max_new - len(self.slot_req[i].out)
               for i in active]
        horizon = min(rem) if self.queue else max(rem)
        return chunk_bucket(max(1, min(horizon, self.chunk)))

    def _chunk_fn(self, n_steps: int) -> Callable:
        if self.paged:
            return paged_decode_chunk_program(
                self.cfg, self.scfg, n_steps, self.page_size,
                policy=self._policy)
        return decode_chunk_program(self.cfg, self.scfg, n_steps,
                                    policy=self._policy)

    # -- one scheduling round: admit, then decode one chunk ---------------
    def step(self) -> None:
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        T = self._pick_chunk(active)
        self._key, sub = jax.random.split(self._key)
        if self.paged:
            (self.caches, self.state, self.pages, toks,
             emitted) = self._chunk_fn(T)(
                self.params, self.caches, self.state, self.pages, sub
            )
            fetch = (toks, emitted, self.state.active, self.pages.free_top)
        else:
            self.caches, self.state, toks, emitted = self._chunk_fn(T)(
                self.params, self.caches, self.state, sub
            )
            fetch = (toks, emitted)
        self.stats.chunks += 1
        self.stats.dispatches += 1
        self.stats.steps += T
        fetched = jax.device_get(fetch)                      # ONE host sync
        toks_np, emit_np = fetched[0], fetched[1]
        self.stats.host_syncs += 1
        self.stats.slot_total_steps += self.B * T
        self.stats.slot_busy_steps += int(emit_np.sum())
        for i in active:
            req = self.slot_req[i]
            for t in range(T):
                if not emit_np[t, i]:
                    break
                req.out.append(int(toks_np[t, i]))
                self.stats.decode_tokens += 1
            hit_eos = req.eos is not None and req.out and \
                req.out[-1] == req.eos
            if len(req.out) >= req.max_new or hit_eos:
                req.done = True
                self.slot_req[i] = None
                self.stats.completed += 1
                if self.paged:
                    self.kv_pool.free(req.rid)
        if self.paged:
            active_np = fetched[2]
            self._stalled = self._stalled + 1 \
                if int(emit_np.sum()) == 0 else 0
            # a slot that deactivated without finishing was denied a page
            # (pool dry / quota hit): requeue its request at the head — it
            # re-prefills from scratch once capacity frees
            oomed = 0
            for i in active:
                req = self.slot_req[i]
                if req is not None and not bool(active_np[i]):
                    self.slot_req[i] = None
                    self.kv_pool.free(req.rid)
                    self.stats.oom_discarded_tokens += len(req.out)
                    req.out.clear()
                    self.queue.appendleft(req)
                    self.stats.oom_requeues += 1
                    oomed += 1
            if oomed:
                self._resident_cap = max(
                    1, sum(r is not None for r in self.slot_req))
            elif self._resident_cap < self.B:
                self._resident_cap += 1
            self.stats.pages_in_use = self.n_pages - int(fetched[3])
            self.stats.peak_pages_in_use = max(
                self.stats.peak_pages_in_use, self.stats.pages_in_use)
            self._admitted_pages_since_sync = 0

    def run(self, *, max_steps: int = 10_000) -> BatcherStats:
        while (self.queue or any(r is not None for r in self.slot_req)) and \
                self.stats.steps < max_steps:
            before = self.stats.dispatches
            self.step()
            if self.stats.dispatches == before and \
                    not any(r is not None for r in self.slot_req):
                break   # starved: queued work cannot be admitted (page limit)
            if self._stalled >= 8:
                break   # page-fault livelock: the pool cannot fit even one
                        # request's footprint at the current quota
        return self.stats
