"""Continuous batching over fixed decode slots — chunked, donated hot path.

The decode program has a fixed batch shape (XLA requirement); the batcher
multiplexes a dynamic request stream onto B fixed slots:

* new requests are prefilled **right-sized** (the joining rows only,
  bucketed to powers of two so the jit cache stays small) and their caches
  scattered into free slots with per-slot ``.at[:, slot].set`` writes — one
  fused admission dispatch, no full-tree ``jnp.where`` merge;
* decode runs in **chunks**: one ``lax.scan`` program advances all slots T
  steps with EOS/max-token detection on device, so the host pays one
  dispatch and one blocking sync per T tokens instead of per token.  T
  adapts to queue pressure (short chunks while requests wait, long chunks
  when the queue is dry) over the same power-of-two buckets;
* cache and slot-state buffers are **donated** into both programs
  (``jax.jit(..., donate_argnums=...)``), so XLA updates the ring-buffer KV
  in place — without donation every token copies the entire cache tree;
* slots free on EOS/max-tokens and are immediately refillable — the
  dynamic-workload serving pattern of the paper's private-cloud scenario,
  with the slot pool playing the role of the core pool at request
  granularity.

Invariants:

* ``self.caches``/``self.state`` always refer to the *latest* donated
  outputs; any previously exported reference is dead.  External consumers
  (e.g. ``ServingExecutor.register_state`` for mid-run resizes) must pull
  through :meth:`live_state` and hand back migrated trees via
  :meth:`adopt_state` — never hold the raw arrays across a step.
* ``slot_req[i] is not None`` ⟺ slot i is active on device; the host mirror
  is reconciled from the fetched ``emitted`` mask after every chunk.
* A slot that finishes mid-chunk keeps decoding with its position frozen,
  overwriting only its own ring slot; admission re-seeds the cache before
  reuse (see ``serving.engine``).

Host-side bookkeeping is numpy; device work happens only in the two jitted
programs.

**Paged mode** (``paged=True``): the per-slot dense ring buffers are replaced
by one pre-allocated pool of fixed-size KV pages (the cache analogue of the
paper's instruction-frame tile) with per-slot page tables — see
``serving.engine.PageState``.  Admission is gated on *page availability*
instead of slot count alone: each joining request reserves its worst-case
footprint (``ceil((prompt_len + max_new)/page_size)`` pages, or just the
prompt pages with ``reserve_pages=False``) in a host-side
:class:`~repro.serving.kv_cache.PagedKVPool` ledger, so the pool can hold
far more slots than dense rings of the same HBM would (slots whose actual
use is below ``max_len`` stop paying for it).  Page faults during decode are
handled on device inside the chunk scan; a slot denied a page (pool dry or
``kv_pages`` quota hit — only possible without reservations) deactivates,
and the host requeues its request at the queue head
(``stats.oom_requeues``) — keeping its generated tokens when
prompt+output still fits the prompt bucket (resume-on-OOM: re-admission
prefills the concatenation instead of restarting).  The single post-chunk
sync additionally carries ``active`` and ``free_top`` so the host ledger
stays reconciled.

**Prefix sharing** (``prefix_cache=True``, paged + pure-attention archs):
admission consults a :class:`~repro.serving.prefix_cache.PrefixCache`
(refcounted radix tree over the pool at page granularity, namespaced by
``Request.namespace``): hits map cached physical pages read-only into the
slot's table and prefill only the uncached suffix
(``engine.cached_admit_program``); misses insert their prefix pages for
the next request — but only with **recurrence evidence** (another pending
request carries the same prefix, or the cache's ghost index saw it
before), so single-use tails never spend cache pages (ownership of
inserted pages moves to the namespace — ``PagedKVPool.share`` — billed
once).  Cache-owned pages are pinned on
device (``PageState.pinned``) so finishing slots never push them to the
free stack; they return only through LRU eviction (admission pressure or a
``set_page_limit`` shrink, which evicts the cache *before* live requests
fault) via ``page_push_program``.

**Deadlines**: a ``Request.deadline`` (in the ``clock`` timebase) already
past at admission time sheds the request (``dropped`` /
``stats.deadline_drops``) instead of starting it hopelessly late.

**Fault guards** (the serving half of the fault-domain story —
``repro.core.faults`` is the hypervisor half): every chunk carries a
non-finite **logit sentinel** — a slot whose logits go NaN/inf is
deactivated on device before a poisoned token can be selected or emitted,
and its request is requeued with its pre-fault tokens intact
(``stats.poisoned_slots``).  An optional **watchdog** (``watchdog_s``)
bounds the wall time of one chunk dispatch+sync and retires the most
suspect slot instead of stalling every other request
(``stats.watchdog_trips``).  An opt-in **page-table audit** (``audit=True``,
paged mode) rides the existing post-chunk sync, cross-checks the fetched
tables against the no-double-mapping invariant (shared prefix pages are
exempt — they are read-only and multi-mapped by design), clears violating
entries, quarantines double-mapped physical pages out of circulation
forever, and requeues the slots whose KV integrity is suspect
(``stats.audit_repairs`` / ``stats.quarantined_pages``).  All three keep
the blast radius at the slot: untouched slots decode the same tokens they
would have without the fault.  ``inject_stall`` / ``inject_kv_corruption``
are seeded-chaos hooks for tests and ``benchmarks/bench_chaos.py``.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import (
    TP_POLICY, check_tp, make_tp_mesh, permute_params_for_tp,
    tp_cache_specs, tp_param_specs, tp_put_replicated, tp_shardings,
)
from repro.models.attention import check_attn_impl
from repro.models.transformer import (
    Caches, init_caches, init_paged_caches, period_structure,
)
from repro.obs import MetricsRegistry, Telemetry
from .config import ServingConfig, config_from_legacy_kwargs
from .kv_cache import PagedKVPool, PageQuotaError, pages_for, tree_bytes
from .prefix_cache import PrefixCache, PrefixNode
from .engine import (
    DraftState,
    PageState,
    ServeConfig,
    SlotState,
    admit_program,
    cached_admit_program,
    chunk_bucket,
    decode_chunk_program,
    init_draft_state,
    init_page_state,
    init_slot_state,
    page_push_program,
    paged_admit_program,
    paged_decode_chunk_program,
    paged_spec_decode_chunk_program,
    spec_decode_chunk_program,
)


@dataclasses.dataclass
class Request:
    """One generation request.

    ``namespace`` keys the shared-prefix cache: requests (possibly from
    different tenants multiplexed on one batcher) share cached prompt pages
    only within a namespace.  Sharing is **opt-in**: the default ``None``
    never shares — callers that want reuse pick a namespace key (and
    thereby accept that admission timing reveals prefix reuse within it).
    Note: prompts are left-padded to the batcher's ``prompt_len`` bucket,
    so only requests whose prompts have equal *total* length align
    positions and can share a prefix (see ``prefix_cache`` module docs).
    ``deadline`` (same clock as the batcher's ``clock`` callable) lets the
    batcher shed the request instead of starting it hopelessly late —
    ``dropped`` marks that outcome (``done`` is set too, with no output).
    """

    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int
    eos: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    namespace: Optional[str] = None
    deadline: Optional[float] = None
    dropped: bool = False
    # set when the request was requeued mid-flight (OOM / poison / watchdog)
    # and re-admitted: its row is left-padded differently than the original
    # prompt, which shifts page alignment for the prefix cache
    resumed: bool = False
    # prefix-cache nodes this request currently pins (internal)
    _prefix_nodes: List[PrefixNode] = dataclasses.field(
        default_factory=list, repr=False)


# Every BatcherStats counter, in declaration order.  Each name is a view
# over the ``serving.<name>`` counter in the batcher's MetricsRegistry.
_STATS_FIELDS: Tuple[str, ...] = (
    "steps",                    # device decode steps executed (Σ chunk T)
    "chunks",                   # decode_chunk dispatches
    "prefills",                 # admission dispatches
    "completed",
    "slot_busy_steps",
    "slot_total_steps",
    "dispatches",               # all jitted dispatches (admit + chunk)
    "host_syncs",               # blocking device→host fetches
    "decode_tokens",            # tokens emitted by decode chunks
    "admit_tokens",             # first tokens emitted at admission
    "cache_bytes",              # resident cache-tree size (donated in place)
    "admit_scatter_bytes",      # bytes scattered at admission (vs. full-tree)
    # paged mode
    "oom_requeues",             # requests requeued after a denied page fault
    "oom_discarded_tokens",     # emitted tokens thrown away by requeues
    "oom_resumed",              # OOM requeues that kept their tokens
    "resumed_tokens_kept",      # tokens kept across requeues (any cause)
    "pages_in_use",             # device-allocated pages after the last sync
    "peak_pages_in_use",
    "peak_resident",            # most simultaneously-resident requests
    # device counters (ride back inside the per-chunk sync, paged modes)
    "device_pages_popped",      # pages popped off the free stack in-scan
    "device_pages_pushed",      # pages pushed back by in-scan frees
    "fault_denied_slots",       # slot-steps denied a page grant in-scan
    "device_draft_accepted",    # draft tokens accepted, counted on-device
    # prefix cache
    "prefix_hits",              # admissions that mapped >= 1 cached page
    "prefill_tokens_skipped",   # prompt tokens served from shared pages
    "prefix_inserts",           # pages newly indexed into the cache
    "prefix_evictions",         # cached pages reclaimed to the free stack
    "shared_pages",             # cache-owned pages right now (gauge)
    # deadlines
    "deadline_drops",           # requests shed before start (past deadline)
    # fault guards (NaN sentinel / watchdog / page-table audit)
    "poisoned_slots",           # slots retired by the non-finite sentinel
    "watchdog_trips",           # chunks that exceeded watchdog_s
    "audit_repairs",            # page-table entries the audit cleared
    "quarantined_pages",        # pool pages permanently out of circulation
    # speculative decode
    "spec_windows",             # draft-and-verify windows with >= 1 commit
    "drafted_tokens",           # draft tokens proposed in those windows
    "accepted_tokens",          # draft tokens the verify pass accepted
    # prefill/decode overlap
    "overlap_rounds",           # rounds with chunk + admission both in flight
    # prefix cache: resumed rows whose shifted padding missed the cache
    "resume_prefix_misses",
    # tensor parallelism
    "remeshes",                 # live tp-width migrations (hypervisor resizes)
)
_STATS_FIELD_SET = frozenset(_STATS_FIELDS)


class BatcherStats:
    """The batcher's counter bundle, now backed by a ``MetricsRegistry``.

    Historically a plain dataclass of ints; each field is now a *view*
    over the ``serving.<field>`` counter in a registry (optionally
    per-tenant labeled), so ``batcher.stats.chunks`` and
    ``registry.counter("serving.chunks", tenant).value`` are literally the
    same number.  The keyword constructor, ``+=`` on fields, and every
    derived ratio property behave exactly as before.
    """

    __slots__ = ("_registry", "_tenant")

    def __init__(self, *, registry: Optional[MetricsRegistry] = None,
                 tenant: Optional[str] = None, **overrides: int):
        object.__setattr__(self, "_registry",
                           registry if registry is not None
                           else MetricsRegistry())
        object.__setattr__(self, "_tenant", tenant)
        for name in _STATS_FIELDS:
            self._registry.counter(f"serving.{name}", self._tenant)
        for name, value in overrides.items():
            if name not in _STATS_FIELD_SET:
                raise TypeError(
                    f"BatcherStats got an unexpected field {name!r}")
            setattr(self, name, value)

    @property
    def registry(self) -> MetricsRegistry:
        return self._registry

    def __getattr__(self, name: str) -> int:
        if name in _STATS_FIELD_SET:
            return self._registry.counter(
                f"serving.{name}", self._tenant).value
        raise AttributeError(name)

    def __setattr__(self, name: str, value) -> None:
        if name in _STATS_FIELD_SET:
            self._registry.counter(
                f"serving.{name}", self._tenant).value = value
        else:
            object.__setattr__(self, name, value)

    def as_dict(self) -> Dict[str, int]:
        return {name: getattr(self, name) for name in _STATS_FIELDS}

    def __repr__(self) -> str:
        body = ", ".join(f"{k}={v}" for k, v in self.as_dict().items())
        return f"BatcherStats({body})"

    @property
    def prefix_tokens_saved(self) -> int:
        """Alias of ``prefill_tokens_skipped``: every prompt token served
        from a shared page is exactly one prefill token not re-run."""
        return self.prefill_tokens_skipped

    @property
    def acceptance_rate(self) -> float:
        """Fraction of drafted tokens the verify pass accepted — the
        speculative win factor: tokens per window = 1 + rate·(W-1)."""
        return self.accepted_tokens / max(self.drafted_tokens, 1)

    @property
    def occupancy(self) -> float:
        return self.slot_busy_steps / max(self.slot_total_steps, 1)

    @property
    def tokens(self) -> int:
        """Tokens actually *delivered*: a restarted (OOM-requeued) request's
        discarded emissions were device work but not throughput — without
        the correction, over-subscribed tokens/s would be inflated by
        exactly the thrashing the residency throttle exists to limit."""
        return self.decode_tokens + self.admit_tokens \
            - self.oom_discarded_tokens

    @property
    def dispatches_per_token(self) -> float:
        return self.dispatches / max(self.tokens, 1)

    @property
    def syncs_per_token(self) -> float:
        return self.host_syncs / max(self.tokens, 1)

    @property
    def decode_dispatches_per_token(self) -> float:
        """Dispatches on the pure-decode path: 1/T when chunks run full."""
        return self.chunks / max(self.decode_tokens, 1)


class ContinuousBatcher:
    """Fixed-slot continuous batcher for one tenant's model.

    Construct with a validated :class:`~repro.serving.config.ServingConfig`::

        ContinuousBatcher(params, cfg, ServingConfig(slots=4, prompt_len=8,
                                                     max_len=32))

    The pre-config keyword constructor
    (``ContinuousBatcher(params, cfg, slots=4, ...)``) still works as a thin
    deprecation shim — every legacy kwarg maps 1:1 onto a config field —
    but emits a ``DeprecationWarning``.
    """

    def __init__(self, params, cfg, config: Optional[ServingConfig] = None,
                 *, policy=None, mesh=None,
                 clock: Optional[Callable[[], float]] = None,
                 telemetry: Optional[Telemetry] = None, **legacy):
        if config is None:
            offending = ", ".join(sorted(legacy)) if legacy else "<none>"
            warnings.warn(
                f"ContinuousBatcher(**kwargs) is deprecated — move the "
                f"legacy kwarg(s) [{offending}] onto a ServingConfig: "
                f"ContinuousBatcher(params, cfg, ServingConfig(...))",
                DeprecationWarning, stacklevel=2)
            config = config_from_legacy_kwargs(**legacy)
        elif legacy:
            raise TypeError(
                f"pass either a ServingConfig or legacy kwargs, not both "
                f"(got config and {sorted(legacy)})")
        self.params = params
        self.cfg = cfg
        self.config = config
        slots, prompt_len = config.slots, config.prompt_len
        paged, page_size = config.paged, config.page_size
        prefix_cache = config.prefix_cache
        self.B = slots
        self.prompt_len = prompt_len
        self.chunk = max(1, config.chunk)
        scfg = ServeConfig(max_len=config.max_len, attn_impl=config.attn_impl,
                           chunk=self.chunk)
        self.scfg = scfg
        # structural / capability rules were validated by ServingConfig;
        # the model-dependent rules live here, where cfg is known
        if cfg.sliding_window:
            check_attn_impl(config.attn_impl, "sliding_window")
        if prefix_cache and (
                any(s.mixer != "attn" for s in period_structure(cfg))
                or cfg.family in ("audio", "vlm")):
            raise ValueError(
                "prefix caching requires a pure-attention arch (SSM state "
                "is not positional; audio/vlm prompts shift positions)")
        if config.speculative and (
                any(s.mixer != "attn" for s in period_structure(cfg))
                or cfg.family in ("audio", "vlm") or cfg.sliding_window):
            raise ValueError(
                "speculative decode requires a pure-attention, "
                "non-sliding-window text arch (SSM state cannot be rolled "
                "back to the accepted prefix)")
        self._policy = policy
        # tensor parallelism: resolve the tenant sub-mesh before any device
        # state is allocated, so params/caches land sharded from the start
        self.tp = int(config.tp)
        self._mesh = None
        self._device = None           # single-device pin (width-1 lease)
        self._host_params = None      # un-permuted host copy, for re-meshing
        if mesh is not None:
            if "tp" not in getattr(mesh, "axis_names", ()):
                raise ValueError(
                    "batcher meshes must be flat ('tp',) meshes "
                    "(distributed.sharding.make_tp_mesh)")
            if int(mesh.shape["tp"]) != self.tp:
                raise ValueError(
                    f"mesh is tp={int(mesh.shape['tp'])} wide but "
                    f"ServingConfig.tp={self.tp}")
        if self.tp > 1:
            if policy is not None:
                raise ValueError(
                    "tp>1 installs its own TPShardPolicy; custom activation "
                    "policies are single-device")
            check_tp(cfg, self.tp)
            self._mesh = mesh if mesh is not None else make_tp_mesh(self.tp)
            self._policy = TP_POLICY
            self._host_params = jax.device_get(params)
            self.params = jax.device_put(
                permute_params_for_tp(self._host_params, cfg, self.tp),
                tp_shardings(self._mesh, tp_param_specs(cfg)))
        elif mesh is not None:
            # a width-1 lease still names WHICH device the tenant runs on
            self._device = list(mesh.devices.flat)[0]
            self.params = jax.device_put(params, self._device)
        self.paged = paged
        self._clock = clock if clock is not None else time.monotonic
        self._has_deadlines = False
        self.queue: Deque[Request] = deque()
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.state: SlotState = init_slot_state(slots)
        self.prefix: Optional[PrefixCache] = None
        if isinstance(prefix_cache, PrefixCache):
            assert prefix_cache.page_size == page_size
            self.prefix = prefix_cache
        elif prefix_cache:
            self.prefix = PrefixCache(page_size)
        if paged:
            self.page_size = max(1, page_size)
            self.max_pages = pages_for(config.max_len, self.page_size)
            # default pool == dense capacity; pass a smaller n_pages to
            # over-subscribe (the bench's equal-HBM capacity argument)
            self.n_pages = config.n_pages if config.n_pages is not None \
                else slots * self.max_pages
            self.reserve_pages = config.reserve_pages
            self._page_limit = min(config.page_quota, self.n_pages) \
                if config.page_quota is not None else self.n_pages
            self.kv_pool = PagedKVPool(self.n_pages, self.page_size)
            self.caches: Caches = init_paged_caches(
                cfg, slots, self.n_pages, self.page_size)
            if not self.caches.kv:
                raise ValueError("paged mode needs at least one attn layer")
            self.pages: Optional[PageState] = init_page_state(
                slots, self.n_pages, self.max_pages, quota=self._page_limit)
            self._admit_fn = paged_admit_program(
                cfg, scfg, policy=self._policy, mesh=self._mesh)
        else:
            self.caches = init_caches(cfg, slots, config.max_len)
            self.pages = None
            self._admit_fn = admit_program(
                cfg, scfg, policy=self._policy, mesh=self._mesh)
        # speculative decode: the chunk unit becomes a draft-and-verify
        # window; the drafter history is device state donated like the rest
        self._spec = bool(config.speculative)
        self._draft_window = config.draft_window
        self._draft_ngram = config.draft_ngram
        self._draft_hist = config.draft_hist
        self.draft: Optional[DraftState] = (
            init_draft_state(slots, config.draft_hist) if self._spec
            else None)
        self._overlap = bool(config.overlap)
        # telemetry: registry backs every BatcherStats field; the tracer
        # (NULL_TRACER by default — zero-cost) records round/chunk spans
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self._tracer = self.telemetry.tracer
        self._track = self.telemetry.track
        self.stats = BatcherStats(registry=self.telemetry.registry,
                                  tenant=self.telemetry.tenant,
                                  cache_bytes=tree_bytes(self.caches))
        # fault guards: watchdog_s bounds the wall time of one chunk
        # dispatch+sync (None = off); audit=True cross-checks the fetched
        # page tables against the no-double-mapping invariant every chunk
        self._watchdog_s = config.watchdog_s
        self._audit = bool(config.audit) and paged
        self._stall: Optional[tuple] = None      # inject_stall chaos hook
        self._quarantined: set = set()           # page ids out of circulation
        self._key = jax.random.PRNGKey(0)
        self._stalled = 0           # consecutive zero-emission paged chunks
        self._admitted_pages_since_sync = 0
        # over-subscription throttle: after a denied page fault, cap
        # residency at the survivors so restarted requests stop thrashing
        # the ones still making progress; recover one slot per clean round
        self._resident_cap = slots
        if self._mesh is not None:
            self._place_state()

    # -- request intake ------------------------------------------------
    def submit(self, req: Request) -> None:
        assert req.prompt.shape[0] <= self.prompt_len
        if self.paged:
            assert self._request_pages(req) <= self.n_pages, \
                "request footprint exceeds the whole page pool"
        if req.deadline is not None:
            self._has_deadlines = True
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _shed_expired(self) -> None:
        """Drop queued requests whose deadline has already passed — serving
        them would burn slots on answers nobody is waiting for."""
        if not self._has_deadlines:
            return
        now = self._clock()
        kept: Deque[Request] = deque()
        for req in self.queue:
            if req.deadline is not None and now > req.deadline:
                req.done = True
                req.dropped = True
                self.stats.deadline_drops += 1
            else:
                kept.append(req)
        self.queue = kept

    # -- paged-mode ledger ----------------------------------------------
    def _request_pages(self, req: Request) -> int:
        """Ledger reservation for one request: its worst-case footprint
        (bucketed prompt + full decode budget) when reserving, prompt pages
        only when running over-subscribed."""
        toks = self.prompt_len + (req.max_new if self.reserve_pages else 0)
        return pages_for(toks, self.page_size)

    def set_page_limit(self, n_pages: int) -> None:
        """Adjust the tenant's ``kv_pages`` lease cap mid-run (hypervisor
        kv resize).  Takes effect on the next dispatch; shrinking below the
        current allocation only blocks further growth — resident pages
        drain as their slots complete.  With a prefix cache attached, a
        shrink **evicts unpinned cache entries first** (shared pages count
        against the lease like any allocation), so the cache pays for the
        smaller lease before live requests start faulting against it."""
        assert self.paged, "page limits only apply to paged batchers"
        self._page_limit = max(0, min(int(n_pages), self.n_pages))
        self.pages = self.pages._replace(quota=jnp.int32(self._page_limit))
        if self.prefix is not None:
            est = self.stats.pages_in_use + self._admitted_pages_since_sync
            if est > self._page_limit:
                self._evict_cached(est - self._page_limit)

    def _evict_cached(self, n: int) -> int:
        """Reclaim up to ``n`` pages from the prefix cache (LRU, refcount-0
        only): drop them from the shared ledger and push them back onto the
        device free stack.  Returns how many pages came back."""
        if self.prefix is None or n <= 0:
            return 0
        pids = self.prefix.evict(n)
        if not pids:
            return 0
        self.kv_pool.drop_shared(pids)
        self.stats.prefix_evictions += len(pids)
        self.stats.shared_pages = self.kv_pool.shared
        # pad the pid vector to a power-of-two bucket (-1 = no-op) so the
        # push program compiles log2(n_pages) shapes, not one per eviction
        width = 1 << (len(pids) - 1).bit_length() if len(pids) > 1 else 1
        vec = np.full((width,), -1, dtype=np.int32)
        vec[: len(pids)] = pids
        self.pages = page_push_program()(self.pages, jnp.asarray(vec))
        self.stats.dispatches += 1
        self.stats.pages_in_use = max(0, self.stats.pages_in_use - len(pids))
        return len(pids)

    def _page_shortfall(self, need: int, pop_need: Optional[int] = None,
                        ) -> int:
        """Pages missing before ``need`` can be admitted: the worst deficit
        over the lease bound, the ledger bound, and (without reservations)
        the device free-stack estimate for ``pop_need`` (the pages the
        admission dispatch will actually pop — the prompt's uncached pages;
        defaults to ``need``).  0 means the admission fits.  Every evicted
        cache page relieves all three bounds at once, so this is exactly
        how many pages an eviction pass must reclaim — evicting a whole
        request footprint instead would flush warm entries that were never
        in the way."""
        if pop_need is None:
            pop_need = need
        short = max(0, self.kv_pool.used + need - self._page_limit)
        short = max(short, need - self.kv_pool.available)
        if not self.reserve_pages:
            # the ledger only reserved prompt pages; residents' decode pages
            # live on device.  Bound admission by the device allocation seen
            # at the last sync (plus prompts admitted since), and keep one
            # page of headroom whenever someone is already resident so at
            # least one slot can take the decode-time fault and progress.
            device_avail = (self.n_pages - self.stats.pages_in_use
                            - self._admitted_pages_since_sync)
            short = max(
                short,
                pop_need + int(any(r is not None for r in self.slot_req))
                - device_avail)
        return short

    def _pages_available(self, need: int, pop_need: Optional[int] = None,
                         ) -> bool:
        return self._page_shortfall(need, pop_need) == 0

    # -- mid-run migration (Hypervisor resize between chunks) -----------
    def live_state(self) -> Dict[str, Any]:
        """Current device state, for ``TwoStageCompiler.reconfigure``
        migration.  Pull-only: the returned arrays are donated (dead) after
        the next step — register this *method* (not its result) with
        ``ServingExecutor.register_state``.  Paged batchers also carry the
        page tables / free stack, so a resize migrates the whole pool."""
        out = {"caches": self.caches, "slots": self.state}
        if self.paged:
            out["pages"] = self.pages
        if self._spec:
            # the drafter history migrates with the caches so re-admitted
            # tenants keep speculating mid-request (tenancy live-state
            # migration moves the whole dict with one device_put)
            out["draft"] = self.draft
        return out

    def adopt_state(self, state: Dict[str, Any]) -> None:
        """Adopt a migrated state tree; decode resumes at the same token."""
        self.caches = state["caches"]
        self.state = state["slots"]
        if self.paged:
            self.pages = state["pages"]
        if self._spec:
            self.draft = state["draft"]

    def _place_state(self) -> None:
        """device_put the donated device state with its layout: KV head axis
        split over the tp mesh, slot/page/draft bookkeeping replicated (or
        everything onto the default device when single-device), so
        steady-state chunks never pay a layout transfer inside a dispatch."""
        mesh = self._mesh
        if mesh is None:
            dev = self._device
            self.caches = jax.device_put(self.caches, dev)
            self.state = jax.device_put(self.state, dev)
            if self.pages is not None:
                self.pages = jax.device_put(self.pages, dev)
            if self.draft is not None:
                self.draft = jax.device_put(self.draft, dev)
            self._key = jax.device_put(self._key, dev)
            return
        self.caches = jax.device_put(
            self.caches,
            tp_shardings(mesh, tp_cache_specs(self.cfg, paged=self.paged)))
        self.state = tp_put_replicated(mesh, self.state)
        if self.pages is not None:
            self.pages = tp_put_replicated(mesh, self.pages)
        if self.draft is not None:
            self.draft = tp_put_replicated(mesh, self.draft)
        self._key = tp_put_replicated(mesh, self._key)

    def remesh(self, tp: Optional[int] = None, *, mesh=None) -> None:
        """Live-migrate this batcher onto a new TP width / device set.

        The hypervisor's elastic-resize path: snapshot the donated device
        state to host (:meth:`live_state`), swap in the new mesh + sharded
        programs (registry hits when the mesh was seen before), re-place
        params — re-permuting the swiglu pack from the kept un-permuted
        host copy, since the column permutation depends on tp — and adopt
        the state back.  State *values* are untouched, so the decode stream
        is token-identical across the move; resident requests, queued
        requests, and the drafter history all ride along.
        """
        if mesh is not None:
            if "tp" not in getattr(mesh, "axis_names", ()):
                raise ValueError(
                    "batcher meshes must be flat ('tp',) meshes "
                    "(distributed.sharding.make_tp_mesh)")
            new_tp = int(mesh.shape["tp"])
            if tp is not None and int(tp) != new_tp:
                raise ValueError(
                    f"tp={tp} conflicts with the mesh width {new_tp}")
        elif tp is None:
            raise ValueError("remesh needs a tp width or a mesh")
        else:
            new_tp = int(tp)
        if new_tp > 1:
            if self.config.attn_impl != "xla":
                raise ValueError(
                    f"tp={new_tp} requires attn_impl='xla' (the "
                    f"{self.config.attn_impl!r} kernels are single-device)")
            check_tp(self.cfg, new_tp)
        if self._host_params is None:
            # currently single-device: the resident params ARE the host
            # layout (no permutation was applied)
            self._host_params = jax.device_get(self.params)
        state = jax.device_get(self.live_state())
        self.config = dataclasses.replace(self.config, tp=new_tp)
        self.tp = new_tp
        if new_tp > 1:
            self._mesh = (mesh if mesh is not None
                          else make_tp_mesh(new_tp))
            self._device = None
            self._policy = TP_POLICY
            self.params = jax.device_put(
                permute_params_for_tp(self._host_params, self.cfg, new_tp),
                tp_shardings(self._mesh, tp_param_specs(self.cfg)))
        else:
            dev = list(mesh.devices.flat)[0] if mesh is not None else None
            self._mesh = None
            self._device = dev
            self._policy = None
            self.params = (jax.device_put(self._host_params, dev)
                           if dev is not None
                           else jax.device_put(self._host_params))
        if self.paged:
            self._admit_fn = paged_admit_program(
                self.cfg, self.scfg, policy=self._policy, mesh=self._mesh)
        else:
            self._admit_fn = admit_program(
                self.cfg, self.scfg, policy=self._policy, mesh=self._mesh)
        self.adopt_state(state)
        self._place_state()
        self.stats.remeshes += 1
        self._tracer.instant("remesh", self._track, args={"tp": new_tp})

    # -- fault guards: requeue, watchdog, page-table audit ----------------
    def inject_stall(self, slot: int, seconds: float) -> None:
        """Chaos hook: add ``seconds`` to the next chunk's measured wall
        time and blame ``slot``, so tests and the chaos bench can trip the
        watchdog deterministically without a real hang."""
        self._stall = (int(slot), float(seconds))

    def inject_kv_corruption(self, slot: int, *,
                             pid: Optional[int] = None) -> None:
        """Chaos hook: overwrite one of ``slot``'s mapped page-table
        entries with ``pid`` (default: an out-of-range id), simulating a
        flipped bit in the table.  Passing another slot's physical id
        forges a double mapping.  ``audit=True`` detects and self-heals
        either on the next chunk sync."""
        assert self.paged, "page corruption applies to paged batchers"
        row = np.asarray(jax.device_get(self.pages.table[slot]))
        mapped = np.nonzero(row >= 0)[0]
        j = int(mapped[0]) if mapped.size else 0
        bad = int(pid) if pid is not None else self.n_pages + 7
        self.pages = self.pages._replace(
            table=self.pages.table.at[slot, j].set(bad))

    def _requeue_slot(self, slot: int, req: Request) -> bool:
        """Retire ``slot``'s request to the queue head.  Generated tokens
        are KEPT when prompt+output still fit the prompt bucket
        (re-admission prefills the concatenation and decoding resumes —
        the resume-on-OOM discipline); otherwise the request restarts from
        its prompt and the discarded emissions stay out of
        ``stats.tokens``.  Returns True when the tokens were kept."""
        self.slot_req[slot] = None
        if self.paged:
            if self.prefix is not None:
                self._release_prefix(req)
            self.kv_pool.free(req.rid)
        kept = bool(req.out) and \
            len(req.prompt) + len(req.out) <= self.prompt_len
        if kept:
            self.stats.resumed_tokens_kept += len(req.out)
            req.resumed = True
        else:
            self.stats.oom_discarded_tokens += len(req.out)
            req.out.clear()
        self.queue.appendleft(req)
        return kept

    def _host_release_slot(self, slot: int) -> None:
        """Host-side analogue of the in-chunk finish path: deactivate
        ``slot`` on device and (paged) push its private pages back to the
        free stack, clearing its table row.  Cache-owned (pinned) pages
        are left to the refcount ledger; quarantined and out-of-range ids
        are never pushed."""
        self.state = self.state._replace(
            active=self.state.active.at[slot].set(False))
        if not self.paged:
            return
        row, pin = jax.device_get(
            (self.pages.table[slot], self.pages.pinned[slot]))
        self.stats.host_syncs += 1
        private = np.asarray(row)[int(pin):]
        pids = [int(p) for p in private
                if 0 <= p < self.n_pages and int(p) not in self._quarantined]
        self.pages = self.pages._replace(
            table=self.pages.table.at[slot].set(-1),
            pinned=self.pages.pinned.at[slot].set(0))
        if pids:
            width = 1 << (len(pids) - 1).bit_length() if len(pids) > 1 else 1
            vec = np.full((width,), -1, dtype=np.int32)
            vec[: len(pids)] = pids
            self.pages = page_push_program()(self.pages, jnp.asarray(vec))
            self.stats.dispatches += 1
            self.stats.pages_in_use = max(
                0, self.stats.pages_in_use - len(pids))

    def _watchdog_trip(self, stall_slot: Optional[int]) -> None:
        """A chunk exceeded ``watchdog_s``: retire the most suspect slot
        (the injected one when the stall was synthetic, else the slot with
        the most generated tokens — the longest-running lane) and requeue
        its request, instead of letting one wedged lane stall every
        request multiplexed on this batcher.  Tokens emitted before the
        trip are kept whenever they still fit the prompt bucket."""
        self.stats.watchdog_trips += 1
        self._tracer.instant("watchdog_trip", self._track)
        candidates = [i for i, r in enumerate(self.slot_req)
                      if r is not None]
        if stall_slot is not None and self.slot_req[stall_slot] is not None:
            victim = stall_slot
        elif candidates:
            victim = max(candidates,
                         key=lambda i: (len(self.slot_req[i].out), -i))
        else:
            return
        req = self.slot_req[victim]
        self._host_release_slot(victim)
        self._requeue_slot(victim, req)

    def _run_audit(self, table_np: np.ndarray) -> None:
        """Cross-check the fetched page tables against the
        no-double-mapping invariant: every physical id maps at most one
        (slot, logical) entry unless it is cache-owned (shared prefix
        pages are read-only and legitimately multi-mapped).  Violations
        self-heal — out-of-range entries are cleared, a double-mapped
        private page is unmapped everywhere and **quarantined** (never
        returned to the free stack; billed to a ``"__quarantine__"``
        ledger owner so admission control sees the shrunken pool) — and
        every slot that lost a mapping is requeued: its KV integrity is
        suspect, but its already-emitted tokens are host-side and kept."""
        shared = self.kv_pool.shared_ids()
        owner: Dict[int, tuple] = {}
        clear: set = set()               # (slot, logical) entries to wipe
        corrupt: set = set()             # pool pids leaving circulation
        suspects: set = set()            # slots whose KV integrity is gone
        B, maxp = table_np.shape
        for i in range(B):
            for j in range(maxp):
                pid = int(table_np[i, j])
                if pid < 0:
                    continue
                if pid >= self.n_pages or pid in self._quarantined:
                    clear.add((i, j))
                    suspects.add(i)
                    continue
                if pid in shared:
                    continue
                prev = owner.get(pid)
                if prev is None:
                    owner[pid] = (i, j)
                else:
                    clear.add(prev)
                    clear.add((i, j))
                    corrupt.add(pid)
                    suspects.add(prev[0])
                    suspects.add(i)
        if not clear:
            return
        entries = sorted(clear)
        rows = jnp.asarray([e[0] for e in entries], dtype=jnp.int32)
        cols = jnp.asarray([e[1] for e in entries], dtype=jnp.int32)
        self.pages = self.pages._replace(
            table=self.pages.table.at[rows, cols].set(-1))
        self.stats.audit_repairs += len(entries)
        self._tracer.instant("audit_repair", self._track,
                             args={"entries": len(entries)})
        new_q = corrupt - self._quarantined
        self._quarantined |= corrupt
        self.stats.quarantined_pages = len(self._quarantined)
        if new_q:
            try:
                self.kv_pool.alloc("__quarantine__", len(new_q))
            except PageQuotaError:
                pass        # ledger over-subscribed; device truth governs
        for i in sorted(suspects):
            req = self.slot_req[i]
            if req is None:
                continue
            self._host_release_slot(i)
            self._requeue_slot(i, req)
        # leak reconciliation: the corrupt entry overwrote some page's only
        # mapping, orphaning it — neither mapped, free, shared, nor
        # quarantined.  Its owner was just requeued, so the contents are
        # dead; the page hardware itself is fine (the *table* was corrupt).
        # Reclaim orphans to the free stack so corruption never shrinks the
        # pool beyond the quarantined pages.
        tab, free_arr, top = jax.device_get(
            (self.pages.table, self.pages.free, self.pages.free_top))
        self.stats.host_syncs += 1
        tab = np.asarray(tab)
        known = set(tab[tab >= 0].tolist())
        known |= set(np.asarray(free_arr)[: int(top)].tolist())
        known |= shared | self._quarantined
        leaked = [p for p in range(self.n_pages) if p not in known]
        if leaked:
            width = 1 << (len(leaked) - 1).bit_length() \
                if len(leaked) > 1 else 1
            vec = np.full((width,), -1, dtype=np.int32)
            vec[: len(leaked)] = leaked
            self.pages = page_push_program()(self.pages, jnp.asarray(vec))
            self.stats.dispatches += 1
            self.stats.audit_repairs += len(leaked)

    # -- admission: right-sized prefill + per-slot scatter ---------------
    def _padded_row(self, req: Request) -> np.ndarray:
        """The request's prompt-bucket row: prompt (plus any tokens kept by
        a resume-on-OOM requeue) left-padded with 0s to ``prompt_len``.
        Memoized per (request, emitted-token count) — the witness scan asks
        for every queued request's row each admission round."""
        cached = getattr(req, "_row_cache", None)
        if cached is not None and cached[0] == len(req.out):
            return cached[1]
        row = np.zeros((self.prompt_len,), dtype=np.int32)
        toks = np.asarray(req.prompt, dtype=np.int32)
        if req.out:
            toks = np.concatenate(
                [toks, np.asarray(req.out, dtype=np.int32)])
        row[self.prompt_len - len(toks):] = toks
        req._row_cache = (len(req.out), row)
        return row

    def _release_prefix(self, req: Request) -> None:
        """Unpin the request's cached-prefix pages (tree refcounts + ledger
        refcounts).  Refcount-0 pages stay cached until an eviction."""
        if req._prefix_nodes:
            self.prefix.release(req._prefix_nodes)
            self.kv_pool.release([n.page_id for n in req._prefix_nodes])
            req._prefix_nodes = []

    def _queue_path_counts(self) -> Dict[Any, int]:
        """How many pending requests carry each page-aligned prefix path —
        the round's sharing witness for the insert heuristic.  Bounded to
        the queue's first 16·B entries so a deep backlog doesn't make
        admission O(queue²); sharing deeper in the queue is still caught by
        the ghost index when those requests reach the front."""
        counts: Dict[Any, int] = {}
        if self.prefix is None:
            return counts
        ps = self.page_size
        max_share = self.prefix.max_shareable(self.prompt_len)
        for n_seen, r in enumerate(self.queue):
            if n_seen >= 16 * self.B:
                break
            if r.namespace is None:
                continue
            row = self._padded_row(r)
            for i in range(max_share):
                key = (r.namespace, i, row[:(i + 1) * ps].tobytes())
                counts[key] = counts.get(key, 0) + 1
        return counts

    def _plan_join(self, req: Request, planned_paths: set,
                   witness: Dict[Any, int]):
        """Prefix-cache plan for one joining request: the cached page path
        (hits), and how many of the following full pages this admission will
        insert.  Inserts are contiguous from the hit depth, capped at the
        deepest prefix with **recurrence evidence** — shared by another
        pending request (queue witness) or seen in an earlier lookup (ghost
        index) — so single-use tails never consume cache pages; and they
        skip paths another join of this same round already claimed (its
        physical ids are unknown until that dispatch's sync, so a duplicate
        maps private pages and converges to sharing on a later round)."""
        if self.prefix is None or req.namespace is None:
            return [], 0
        row = self._padded_row(req)
        max_share = self.prefix.max_shareable(self.prompt_len)
        nodes = self.prefix.lookup(req.namespace, row, max_pages=max_share)
        if req.resumed and not nodes:
            # the resume-on-OOM row (prompt + kept tokens) is left-padded
            # differently than the original prompt, so it cannot hit the
            # pages the original inserted.  The lookup above IS the
            # re-attempt — it aligns with other requests resumed at the
            # same output length (and the note_seen below indexes this
            # shifted row so recurring resumes converge to sharing) — but a
            # miss here is a distinct phenomenon from a cold prompt:
            # count it so capacity planning can see resume-induced misses.
            self.stats.resume_prefix_misses += 1
        seen_depth = self.prefix.note_seen(req.namespace, row,
                                           max_pages=max_share)
        ps = self.page_size
        queue_depth = 0
        for i in range(max_share):
            key = (req.namespace, i, row[:(i + 1) * ps].tobytes())
            if witness.get(key, 0) < 2:     # this request counts once
                break
            queue_depth = i + 1
        worth = max(seen_depth, queue_depth, len(nodes))
        inserts = 0
        for i in range(len(nodes), min(max_share, worth)):
            path = (req.namespace, tuple(int(t) for t in row[:(i + 1) * ps]))
            if path in planned_paths:
                break
            planned_paths.add(path)
            inserts += 1
        return nodes, inserts

    def _admit(self, *, defer: bool = False) -> List[Dict[str, Any]]:
        """Admission planning + prefill dispatch.  With ``defer=False`` the
        post-dispatch host work (reading first tokens, completing
        done-at-admission requests, prefix inserts, draft seeding) happens
        inline and ``[]`` is returned; with ``defer=True`` each dispatch is
        returned as a pending record for :meth:`_finish_admit` — the overlap
        path dispatches admission behind the in-flight decode chunk and
        merges both at one point per round."""
        self._shed_expired()
        free = self._free_slots()
        if not free or not self.queue:
            return []
        if not self.paged:
            return self._admit_dense(free, defer=defer)
        joins: List[Dict[str, Any]] = []
        planned_paths: set = set()
        witness = self._queue_path_counts()
        resident = sum(r is not None for r in self.slot_req)
        prompt_pages = pages_for(self.prompt_len, self.page_size)
        while free and self.queue:
            if resident + len(joins) >= self._resident_cap:
                break
            req = self.queue[0]
            nodes, inserts = self._plan_join(req, planned_paths, witness)
            k = len(nodes)
            if nodes:
                # pin the hit path NOW: the pressure-eviction below must
                # never reclaim pages this join is about to map
                self.prefix.acquire(nodes)
                self.kv_pool.acquire([n.page_id for n in nodes])
                req._prefix_nodes = list(nodes)
            # admission by page availability: the queue head joins only when
            # its ledger reservation (minus cache-served pages) fits the
            # pool AND the lease cap (head-of-line — a later smaller request
            # never jumps); under pressure, LRU cache entries are evicted
            # back to the free stack before giving up
            need = self._request_pages(req) - k
            pop = prompt_pages - k
            short = self._page_shortfall(need, pop)
            if short:
                self._evict_cached(short)
                if not self._pages_available(need, pop):
                    if nodes:
                        self._release_prefix(req)
                    break
            self.kv_pool.alloc(req.rid, need)
            if nodes:
                self.stats.prefix_hits += 1
                self.stats.prefill_tokens_skipped += k * self.page_size
            self._admitted_pages_since_sync += pop
            joins.append({"slot": free.pop(0), "req": self.queue.popleft(),
                          "k": k, "pin": k + inserts, "pop": pop,
                          "nodes": nodes})
        if not joins:
            return []
        # one dispatch per cached-prefix depth: the suffix length is a
        # static program shape (bounded by prompt_len / page_size programs)
        by_depth: Dict[int, List[Dict[str, Any]]] = {}
        for join in joins:
            by_depth.setdefault(join["k"], []).append(join)
        pending = [self._dispatch_paged(by_depth[k], k)
                   for k in sorted(by_depth)]
        self.stats.shared_pages = self.kv_pool.shared
        if defer:
            return pending
        for rec in pending:
            self._finish_admit(rec)
        return []

    def _admit_dense(self, free: List[int],
                     *, defer: bool = False) -> List[Dict[str, Any]]:
        """The original dense-ring admission path (no paging)."""
        joins = []
        while free and self.queue:
            joins.append({"slot": free.pop(0), "req": self.queue.popleft()})
        n = len(joins)
        nb = min(1 << (n - 1).bit_length() if n > 1 else 1, self.B)
        toks = np.zeros((nb, self.prompt_len), dtype=np.int32)
        slots = np.zeros((nb,), dtype=np.int32)
        budget = np.zeros((nb,), dtype=np.int32)
        eos = np.full((nb,), -1, dtype=np.int32)
        for j, join in enumerate(joins):
            slot, req = join["slot"], join["req"]
            toks[j] = self._padded_row(req)
            slots[j] = slot
            budget[j] = req.max_new - len(req.out)
            if req.eos is not None:
                eos[j] = req.eos
        # pad a partial bucket by repeating row 0: duplicate-index scatters
        # then write identical values, which is deterministic
        for j in range(n, nb):
            toks[j] = toks[0]
            slots[j] = slots[0]
            budget[j] = budget[0]
            eos[j] = eos[0]
        pos0 = np.full((nb,), self.prompt_len, dtype=np.int32)
        nxt, self.caches, self.state = self._admit_fn(
            self.params, {"tokens": jnp.asarray(toks)}, self.caches,
            self.state, jnp.asarray(slots), jnp.asarray(pos0),
            jnp.asarray(budget), jnp.asarray(eos),
        )
        self.stats.prefills += 1
        self.stats.dispatches += 1
        self.stats.admit_scatter_bytes += int(
            self.stats.cache_bytes * nb / max(self.B, 1)
        )
        rec = {"kind": "dense", "joins": joins, "nxt": nxt}
        if defer:
            return [rec]
        self._finish_admit(rec)
        return []

    def _dispatch_paged(self, group: List[Dict[str, Any]],
                        k: int) -> Dict[str, Any]:
        """One paged admission dispatch for joins sharing ``k`` cached
        prefix pages: cold program at k == 0, cached-suffix program
        otherwise.  Both return the written page-table rows, from which the
        planned full-page inserts learn their physical ids.  Returns the
        pending record for :meth:`_finish_admit` (no host sync here)."""
        n = len(group)
        nb = min(1 << (n - 1).bit_length() if n > 1 else 1, self.B)
        ps = self.page_size
        S = self.prompt_len - k * ps
        toks = np.zeros((nb, S), dtype=np.int32)
        slots = np.zeros((nb,), dtype=np.int32)
        budget = np.zeros((nb,), dtype=np.int32)
        eos = np.full((nb,), -1, dtype=np.int32)
        pin = np.zeros((nb,), dtype=np.int32)
        pids = np.zeros((nb, max(k, 1)), dtype=np.int32)
        rows = [self._padded_row(join["req"]) for join in group]
        for j, join in enumerate(group):
            req = join["req"]
            toks[j] = rows[j][k * ps:]
            slots[j] = join["slot"]
            budget[j] = req.max_new - len(req.out)
            if req.eos is not None:
                eos[j] = req.eos
            pin[j] = join["pin"]
            if k:
                pids[j] = [node.page_id for node in join["nodes"]]
        for j in range(n, nb):        # duplicate-pad with row 0 (see above)
            toks[j] = toks[0]
            slots[j] = slots[0]
            budget[j] = budget[0]
            eos[j] = eos[0]
            pin[j] = pin[0]
            pids[j] = pids[0]
        pos0 = np.full((nb,), self.prompt_len, dtype=np.int32)
        real = np.zeros((nb,), dtype=bool)
        real[:n] = True
        if k:
            fn = cached_admit_program(self.cfg, self.scfg, k,
                                      policy=self._policy, mesh=self._mesh)
            nxt, self.caches, self.state, self.pages, out_rows = fn(
                self.params, {"tokens": jnp.asarray(toks)}, self.caches,
                self.state, self.pages, jnp.asarray(slots),
                jnp.asarray(pos0), jnp.asarray(budget), jnp.asarray(eos),
                jnp.asarray(real), jnp.asarray(pids), jnp.asarray(pin),
            )
        else:
            nxt, self.caches, self.state, self.pages, out_rows = \
                self._admit_fn(
                    self.params, {"tokens": jnp.asarray(toks)}, self.caches,
                    self.state, self.pages, jnp.asarray(slots),
                    jnp.asarray(pos0), jnp.asarray(budget),
                    jnp.asarray(eos), jnp.asarray(real), jnp.asarray(pin),
                )
        self.stats.prefills += 1
        self.stats.dispatches += 1
        self.stats.admit_scatter_bytes += int(
            self.stats.cache_bytes * nb * S
            / max(self.B * self.prompt_len, 1)
        )
        return {"kind": "paged", "joins": group, "k": k, "nxt": nxt,
                "out_rows": out_rows, "rows": rows}

    def _finish_admit(self, rec: Dict[str, Any]) -> None:
        """Post-dispatch half of one admission: read the first tokens (one
        host sync per record), append them, complete done-at-admission
        requests, run the planned prefix inserts, and seed the drafter
        history for the slots that stay resident."""
        k = rec.get("k", 0)
        if rec["kind"] == "paged":
            nxt_np, rows_np = jax.device_get((rec["nxt"], rec["out_rows"]))
        else:
            nxt_np, rows_np = np.asarray(jax.device_get(rec["nxt"])), None
        self.stats.host_syncs += 1
        seeds: List[Tuple[int, Request]] = []
        for j, join in enumerate(rec["joins"]):
            slot, req = join["slot"], join["req"]
            tok = int(nxt_np[j])
            req.out.append(tok)
            self.stats.admit_tokens += 1
            hit_eos = req.eos is not None and tok == req.eos
            if len(req.out) >= req.max_new or hit_eos:
                req.done = True
                self.stats.completed += 1
                if rec["kind"] == "paged":
                    if self.prefix is not None:
                        self._release_prefix(req)
                    self.kv_pool.free(req.rid)
                    # done at admission: the device never popped its prompt
                    # pages (a non-activating row allocates nothing), so
                    # take it back out of the since-sync estimate — else
                    # admit-only rounds leak the counter and starve
                    # over-subscribed admission with the pool entirely free
                    self._admitted_pages_since_sync -= join["pop"]
                continue
            self.slot_req[slot] = req
            seeds.append((slot, req))
            inserts = join.get("pin", 0) - k
            if inserts > 0:
                new_pids = rows_np[j, k:join["pin"]]
                if (new_pids >= 0).all():
                    created = self.prefix.insert(
                        req.namespace, rec["rows"][j], new_pids,
                        start_page=k)
                    assert len(created) == inserts, (created, inserts)
                    cpids = [node.page_id for node in created]
                    self.kv_pool.share(req.rid, req.namespace, cpids)
                    self.kv_pool.acquire(cpids)
                    self.prefix.acquire(created)
                    req._prefix_nodes.extend(created)
                    self.stats.prefix_inserts += len(created)
        if self._spec and seeds:
            self._seed_draft(seeds)
        self.stats.peak_resident = max(
            self.stats.peak_resident,
            sum(r is not None for r in self.slot_req))

    def _seed_draft(self, seeds: List[Tuple[int, Request]]) -> None:
        """Seed the drafter history for freshly admitted slots from the
        host-known token stream (prompt + emitted tokens, newest last) —
        one fused scatter per admission round, no sync.  Resumed requests
        re-seed with their kept output, so the n-gram index warms back up
        immediately after a migration or requeue."""
        N = self._draft_hist
        rows = np.full((len(seeds), N), -1, dtype=np.int32)
        ns = np.zeros((len(seeds),), dtype=np.int32)
        slots = np.array([s for s, _ in seeds], dtype=np.int32)
        for j, (_, req) in enumerate(seeds):
            toks = np.asarray(req.prompt, dtype=np.int32)
            if req.out:
                toks = np.concatenate(
                    [toks, np.asarray(req.out, dtype=np.int32)])
            tail = toks[-N:]
            rows[j, N - len(tail):] = tail
            ns[j] = len(tail)
        idx = jnp.asarray(slots)
        self.draft = DraftState(
            hist=self.draft.hist.at[idx].set(jnp.asarray(rows)),
            n=self.draft.n.at[idx].set(jnp.asarray(ns)),
        )

    # -- chunk sizing: adaptive to queue pressure ------------------------
    def _pick_chunk(self, active: List[int]) -> int:
        """Queue pressure → short chunks (the earliest completion bounds
        admission latency); dry queue → chunks up to the longest remaining
        budget.  Sizes snap to power-of-two buckets (bounded jit cache)."""
        rem = [self.slot_req[i].max_new - len(self.slot_req[i].out)
               for i in active]
        horizon = min(rem) if self.queue else max(rem)
        return chunk_bucket(max(1, min(horizon, self.chunk)))

    def _chunk_fn(self, n_steps: int) -> Callable:
        if self._spec:
            if self.paged:
                return paged_spec_decode_chunk_program(
                    self.cfg, self.scfg, n_steps, self._draft_window,
                    self._draft_ngram, self.page_size, policy=self._policy,
                    mesh=self._mesh)
            return spec_decode_chunk_program(
                self.cfg, self.scfg, n_steps, self._draft_window,
                self._draft_ngram, policy=self._policy, mesh=self._mesh)
        if self.paged:
            return paged_decode_chunk_program(
                self.cfg, self.scfg, n_steps, self.page_size,
                policy=self._policy, mesh=self._mesh)
        return decode_chunk_program(self.cfg, self.scfg, n_steps,
                                    policy=self._policy, mesh=self._mesh)

    def _dispatch_chunk(self, active: List[int]) -> Dict[str, Any]:
        """Dispatch one decode chunk (speculative: T draft-and-verify
        windows; otherwise T decode steps) without syncing; returns the
        pending record for :meth:`_finish_chunk`.  When admission will be
        dispatched behind this chunk (overlap), the fetch handles that the
        admit program would donate are snapshotted with cheap device-side
        copies first."""
        T = self._pick_chunk(active)
        self._key, sub = jax.random.split(self._key)
        t0 = self._clock()
        ctr = None     # (4,) int32 device counters, paged modes only
        if self._spec:
            if self.paged:
                (self.caches, self.state, self.pages, self.draft, toks,
                 emitted, poisoned, ctr) = self._chunk_fn(T)(
                    self.params, self.caches, self.state, self.pages,
                    self.draft, sub)
            else:
                (self.caches, self.state, self.draft, toks, emitted,
                 poisoned) = self._chunk_fn(T)(
                    self.params, self.caches, self.state, self.draft, sub)
            self.stats.steps += T * self._draft_window
        elif self.paged:
            (self.caches, self.state, self.pages, toks, emitted,
             poisoned, ctr) = self._chunk_fn(T)(
                self.params, self.caches, self.state, self.pages, sub
            )
            self.stats.steps += T
        else:
            self.caches, self.state, toks, emitted, poisoned = \
                self._chunk_fn(T)(self.params, self.caches, self.state, sub)
            self.stats.steps += T
        fetch = (toks, emitted, poisoned)
        if self.paged:
            act, top = self.state.active, self.pages.free_top
            tab = self.pages.table if self._audit else None
            if self._overlap and self.queue and \
                    any(r is None for r in self.slot_req):
                # an admission CAN dispatch behind this chunk this round
                # (queued work + a free slot), and the admit program donates
                # state/pages: copy the few arrays this round's sync still
                # needs so the fetch survives the donation (B bools + a
                # scalar + the table).  Rounds with nothing to admit skip
                # the copies — the donation never happens.
                act, top = jnp.copy(act), jnp.copy(top)
                tab = jnp.copy(tab) if tab is not None else None
            fetch += (act, top)
            if tab is not None:
                fetch += (tab,)
            # the device-counter vector rides LAST in the same fetch (it is
            # a fresh chunk output, never donated, so no copy needed even
            # when overlap admission dispatches behind this chunk)
            fetch += (ctr,)
        self.stats.chunks += 1
        self.stats.dispatches += 1
        if self._tracer.enabled:
            self._tracer.complete("dispatch", self._track, t0,
                                  self._clock() - t0,
                                  {"T": T, "active": len(active)})
        return {"fetch": fetch, "t0": t0, "T": T, "active": active}

    def _finish_chunk(self, pending: Dict[str, Any],
                      *, keep_admitted_pages: int = 0) -> None:
        """Sync one dispatched chunk and run all host bookkeeping: token
        emission, completion, poison/OOM requeues, page accounting, audit,
        watchdog.  ``keep_admitted_pages`` is the number of pages admission
        dispatched *behind* this chunk has popped — the fetched ``free_top``
        predates those pops, so they survive the counter reset."""
        T, active = pending["T"], pending["active"]
        t_sync0 = self._clock() if self._tracer.enabled else 0.0
        fetched = jax.device_get(pending["fetch"])           # ONE host sync
        elapsed = self._clock() - pending["t0"]
        if self._tracer.enabled:
            t_end = pending["t0"] + elapsed
            self._tracer.complete("host_sync", self._track, t_sync0,
                                  t_end - t_sync0)
            self._tracer.complete("chunk", self._track, pending["t0"],
                                  elapsed, {"T": T, "slots": len(active)})
        stall_slot: Optional[int] = None
        if self._stall is not None:
            stall_slot, extra = self._stall
            self._stall = None
            elapsed += extra
        toks_np, emit_np, poison_np = fetched[0], fetched[1], fetched[2]
        self.stats.host_syncs += 1
        if self._spec:
            # toks/emitted are (T, B, W); emitted is a per-window prefix
            # mask over the committed tokens.  Busy/total measure *query
            # positions*, so occupancy now reflects speculative efficiency
            # (rejected drafts are idle device work).
            W = self._draft_window
            self.stats.slot_total_steps += self.B * T * W
            self.stats.slot_busy_steps += int(emit_np.sum())
            for i in active:
                req = self.slot_req[i]
                for t in range(T):
                    c = int(emit_np[t, i].sum())
                    if c == 0:
                        break       # deactivated (EOS/budget/OOM/poison)
                    req.out.extend(int(x) for x in toks_np[t, i, :c])
                    self.stats.decode_tokens += c
                    self.stats.spec_windows += 1
                    self.stats.drafted_tokens += W - 1
                    self.stats.accepted_tokens += c - 1
                self._maybe_complete(i, req)
        else:
            self.stats.slot_total_steps += self.B * T
            self.stats.slot_busy_steps += int(emit_np.sum())
            for i in active:
                req = self.slot_req[i]
                for t in range(T):
                    if not emit_np[t, i]:
                        break
                    req.out.append(int(toks_np[t, i]))
                    self.stats.decode_tokens += 1
                self._maybe_complete(i, req)
        # non-finite sentinel: the device deactivated the flagged slots
        # before selecting or emitting a token (and, paged, recycled their
        # pages in the same step), so no poisoned value reached any output
        # stream; requeue the victims — pre-fault tokens are host-side
        # and survive
        for i in active:
            req = self.slot_req[i]
            if req is not None and bool(poison_np[i]):
                self.stats.poisoned_slots += 1
                self._tracer.instant("poisoned_slot", self._track,
                                     args={"slot": i})
                self._requeue_slot(i, req)
        if self.paged:
            active_np = fetched[3]
            # device counters: in-scan paging/accept activity that rode
            # back inside this same sync (last element of the fetch)
            ctr_np = fetched[-1]
            self.stats.device_pages_popped += int(ctr_np[0])
            self.stats.device_pages_pushed += int(ctr_np[1])
            self.stats.fault_denied_slots += int(ctr_np[2])
            self.stats.device_draft_accepted += int(ctr_np[3])
            self._stalled = self._stalled + 1 \
                if int(emit_np.sum()) == 0 else 0
            # a slot that deactivated without finishing was denied a page
            # (pool dry / quota hit): requeue its request at the head.  When
            # prompt + generated still fit the prompt bucket, the generated
            # tokens are KEPT — re-admission prefills prompt+output and
            # decoding resumes where the eviction cut it off; only an
            # overflowing request restarts from its prompt (the discarded
            # emissions stay out of ``stats.tokens``).  Note the resumed
            # row is left-padded differently than the original prompt, so
            # it does NOT hit the original's cached prefix pages — only
            # other requests resumed at the same output length align
            # (counted as ``resume_prefix_misses`` at re-admission)
            oomed = 0
            for i in active:
                req = self.slot_req[i]
                if req is not None and not bool(active_np[i]):
                    self._tracer.instant("oom_requeue", self._track,
                                         args={"slot": i})
                    if self._requeue_slot(i, req):
                        self.stats.oom_resumed += 1
                    self.stats.oom_requeues += 1
                    oomed += 1
            if oomed:
                self._resident_cap = max(
                    1, sum(r is not None for r in self.slot_req))
            elif self._resident_cap < self.B:
                self._resident_cap += 1
            self.stats.pages_in_use = self.n_pages - int(fetched[4])
            self.stats.peak_pages_in_use = max(
                self.stats.peak_pages_in_use, self.stats.pages_in_use)
            self._admitted_pages_since_sync = keep_admitted_pages
            if self._audit:
                self._run_audit(np.asarray(fetched[5]))
        if self._watchdog_s is not None and elapsed > self._watchdog_s:
            self._watchdog_trip(stall_slot)

    def _maybe_complete(self, slot: int, req: Request) -> None:
        """Retire ``slot`` if its request just hit EOS or its budget."""
        hit_eos = req.eos is not None and req.out and req.out[-1] == req.eos
        if len(req.out) >= req.max_new or hit_eos:
            req.done = True
            self.slot_req[slot] = None
            self.stats.completed += 1
            if self.paged:
                if self.prefix is not None:
                    self._release_prefix(req)
                self.kv_pool.free(req.rid)

    # -- one scheduling round ---------------------------------------------
    def step(self) -> None:
        """One scheduling round.

        Serial (default): admit, then decode one chunk — two dispatches,
        two syncs, strictly ordered.

        Overlap (``overlap=True``): dispatch the decode chunk first
        (no sync), then run admission **behind it** — all of admission's
        host-side planning (queue scan, prefix lookups, row packing) plus
        its prefill dispatch happen while the chunk is still computing, and
        the device serializes the two programs through the donated cache
        tree.  One merge point per round: the chunk's sync, then each
        admission's.  The chunk ran against pre-admission state, so its
        fetched ``active``/``free_top`` never see the new slots; this
        round's admission pops are carried across the counter reset."""
        if not self._overlap:
            with self._tracer.span("round", self._track):
                with self._tracer.span("admission", self._track):
                    self._admit()
                active = [i for i, r in enumerate(self.slot_req)
                          if r is not None]
                if not active:
                    return
                self._finish_chunk(self._dispatch_chunk(active))
            return
        with self._tracer.span("round", self._track):
            active = [i for i, r in enumerate(self.slot_req)
                      if r is not None]
            pending = self._dispatch_chunk(active) if active else None
            pops_before = self._admitted_pages_since_sync
            with self._tracer.span("admission", self._track):
                admits = self._admit(defer=True)
            round_pops = self._admitted_pages_since_sync - pops_before
            if pending is not None and admits:
                self.stats.overlap_rounds += 1
                self._tracer.instant("overlap_merge", self._track,
                                     args={"admits": len(admits)})
            if pending is not None:
                self._finish_chunk(pending, keep_admitted_pages=round_pops)
            for rec in admits:
                self._finish_admit(rec)

    def run(self, *, max_steps: int = 10_000) -> BatcherStats:
        while (self.queue or any(r is not None for r in self.slot_req)) and \
                self.stats.steps < max_steps:
            before = self.stats.dispatches
            self.step()
            if self.stats.dispatches == before and \
                    not any(r is not None for r in self.slot_req):
                break   # starved: queued work cannot be admitted (page limit)
            if self._stalled >= 8:
                break   # page-fault livelock: the pool cannot fit even one
                        # request's footprint at the current quota
        return self.stats
