"""Continuous batching over fixed decode slots.

The decode program has a fixed batch shape (XLA requirement); the batcher
multiplexes a dynamic request stream onto B fixed slots:

* new requests are prefillled (padded to the slot prompt length) and their
  caches scattered into free slots;
* every decode step advances all active slots together;
* slots free on EOS/max-tokens and are immediately refillable — the
  dynamic-workload serving pattern of the paper's private-cloud scenario,
  with the slot pool playing the role of the core pool at request
  granularity.

Host-side bookkeeping is numpy; device work happens only in the two jitted
steps.  (Paged/block KV is out of scope — the ring-buffer cache is already
position-indexed, so slot reuse is a pure overwrite.)
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Caches
from .engine import ServeConfig, make_prefill_step, make_serve_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int
    eos: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class BatcherStats:
    steps: int = 0
    prefills: int = 0
    completed: int = 0
    slot_busy_steps: int = 0
    slot_total_steps: int = 0

    @property
    def occupancy(self) -> float:
        return self.slot_busy_steps / max(self.slot_total_steps, 1)


class ContinuousBatcher:
    """Fixed-slot continuous batcher for one tenant's model."""

    def __init__(self, params, cfg, *, slots: int, prompt_len: int,
                 max_len: int, policy=None, attn_impl: str = "xla"):
        self.params = params
        self.cfg = cfg
        self.B = slots
        self.prompt_len = prompt_len
        scfg = ServeConfig(max_len=max_len, attn_impl=attn_impl)
        self.scfg = scfg
        self._prefill = jax.jit(make_prefill_step(cfg, scfg, policy=policy))
        self._serve = jax.jit(make_serve_step(cfg, scfg, policy=policy))
        self.queue: Deque[Request] = deque()
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_pos = np.zeros(slots, dtype=np.int32)
        self.slot_tok = np.zeros(slots, dtype=np.int32)
        self.caches: Optional[Caches] = None
        self.stats = BatcherStats()
        self._key = jax.random.PRNGKey(0)

    # -- request intake ------------------------------------------------
    def submit(self, req: Request) -> None:
        assert req.prompt.shape[0] <= self.prompt_len
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    # -- admission: batched prefill into free slots ---------------------
    def _admit(self) -> None:
        free = self._free_slots()
        if not free or not self.queue:
            return
        joins = []
        while free and self.queue:
            joins.append((free.pop(0), self.queue.popleft()))
        # pad prompts (left-pad with 0s; positions start at pad offset)
        B = self.B
        toks = np.zeros((B, self.prompt_len), dtype=np.int32)
        for slot, req in joins:
            p = req.prompt
            toks[slot, self.prompt_len - len(p):] = p
        logits, new_caches = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        self.stats.prefills += 1
        if self.caches is None:
            self.caches = new_caches
        else:
            sel = np.zeros((B,), dtype=bool)
            for slot, _ in joins:
                sel[slot] = True
            selj = jnp.asarray(sel)

            def merge(old, new):
                # batch axis position differs per leaf rank: caches leaves are
                # (nb, B, ...) for kv/ssm, broadcast select on axis 1
                cond = selj.reshape((1, -1) + (1,) * (old.ndim - 2))
                return jnp.where(cond, new, old)

            self.caches = jax.tree.map(merge, self.caches, new_caches)
        nxt = np.asarray(jnp.argmax(logits[..., : self.cfg.vocab], axis=-1))
        for slot, req in joins:
            self.slot_req[slot] = req
            self.slot_pos[slot] = self.prompt_len
            self.slot_tok[slot] = nxt[slot]
            req.out.append(int(nxt[slot]))

    # -- one decode step over all slots ---------------------------------
    def step(self) -> None:
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        self.stats.slot_total_steps += self.B
        self.stats.slot_busy_steps += len(active)
        if not active:
            return
        self._key, sub = jax.random.split(self._key)
        toks, logits, self.caches = self._serve(
            self.params, jnp.asarray(self.slot_tok), self.caches,
            jnp.asarray(self.slot_pos), sub,
        )
        self.stats.steps += 1
        toks_np = np.asarray(toks)
        self.slot_pos[active] += 1
        for i in active:
            req = self.slot_req[i]
            tok = int(toks_np[i])
            req.out.append(tok)
            self.slot_tok[i] = tok
            hit_eos = req.eos is not None and tok == req.eos
            if len(req.out) >= req.max_new or hit_eos:
                req.done = True
                self.slot_req[i] = None
                self.stats.completed += 1

    def run(self, *, max_steps: int = 10_000) -> BatcherStats:
        while (self.queue or any(r is not None for r in self.slot_req)) and \
                self.stats.steps < max_steps:
            self.step()
        return self.stats
