"""Continuous batching over fixed decode slots — chunked, donated hot path.

The decode program has a fixed batch shape (XLA requirement); the batcher
multiplexes a dynamic request stream onto B fixed slots:

* new requests are prefilled **right-sized** (the joining rows only,
  bucketed to powers of two so the jit cache stays small) and their caches
  scattered into free slots with per-slot ``.at[:, slot].set`` writes — one
  fused admission dispatch, no full-tree ``jnp.where`` merge;
* decode runs in **chunks**: one ``lax.scan`` program advances all slots T
  steps with EOS/max-token detection on device, so the host pays one
  dispatch and one blocking sync per T tokens instead of per token.  T
  adapts to queue pressure (short chunks while requests wait, long chunks
  when the queue is dry) over the same power-of-two buckets;
* cache and slot-state buffers are **donated** into both programs
  (``jax.jit(..., donate_argnums=...)``), so XLA updates the ring-buffer KV
  in place — without donation every token copies the entire cache tree;
* slots free on EOS/max-tokens and are immediately refillable — the
  dynamic-workload serving pattern of the paper's private-cloud scenario,
  with the slot pool playing the role of the core pool at request
  granularity.

Invariants:

* ``self.caches``/``self.state`` always refer to the *latest* donated
  outputs; any previously exported reference is dead.  External consumers
  (e.g. ``ServingExecutor.register_state`` for mid-run resizes) must pull
  through :meth:`live_state` and hand back migrated trees via
  :meth:`adopt_state` — never hold the raw arrays across a step.
* ``slot_req[i] is not None`` ⟺ slot i is active on device; the host mirror
  is reconciled from the fetched ``emitted`` mask after every chunk.
* A slot that finishes mid-chunk keeps decoding with its position frozen,
  overwriting only its own ring slot; admission re-seeds the cache before
  reuse (see ``serving.engine``).

Host-side bookkeeping is numpy; device work happens only in the two jitted
programs.  (Paged/block KV is out of scope — the ring-buffer cache is
position-indexed, so slot reuse is a pure overwrite.)
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Caches, init_caches
from .kv_cache import tree_bytes
from .engine import (
    ServeConfig,
    SlotState,
    admit_program,
    chunk_bucket,
    decode_chunk_program,
    init_slot_state,
)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int
    eos: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class BatcherStats:
    steps: int = 0               # device decode steps executed (Σ chunk T)
    chunks: int = 0              # decode_chunk dispatches
    prefills: int = 0            # admission dispatches
    completed: int = 0
    slot_busy_steps: int = 0
    slot_total_steps: int = 0
    dispatches: int = 0          # all jitted dispatches (admit + chunk)
    host_syncs: int = 0          # blocking device→host fetches
    decode_tokens: int = 0       # tokens emitted by decode chunks
    admit_tokens: int = 0        # first tokens emitted at admission
    cache_bytes: int = 0         # resident cache-tree size (donated in place)
    admit_scatter_bytes: int = 0  # bytes scattered at admission (vs. full-tree)

    @property
    def occupancy(self) -> float:
        return self.slot_busy_steps / max(self.slot_total_steps, 1)

    @property
    def tokens(self) -> int:
        return self.decode_tokens + self.admit_tokens

    @property
    def dispatches_per_token(self) -> float:
        return self.dispatches / max(self.tokens, 1)

    @property
    def syncs_per_token(self) -> float:
        return self.host_syncs / max(self.tokens, 1)

    @property
    def decode_dispatches_per_token(self) -> float:
        """Dispatches on the pure-decode path: 1/T when chunks run full."""
        return self.chunks / max(self.decode_tokens, 1)


class ContinuousBatcher:
    """Fixed-slot continuous batcher for one tenant's model."""

    def __init__(self, params, cfg, *, slots: int, prompt_len: int,
                 max_len: int, policy=None, attn_impl: str = "xla",
                 chunk: int = 8):
        self.params = params
        self.cfg = cfg
        self.B = slots
        self.prompt_len = prompt_len
        self.chunk = max(1, chunk)
        scfg = ServeConfig(max_len=max_len, attn_impl=attn_impl,
                           chunk=self.chunk)
        self.scfg = scfg
        self._policy = policy
        self._admit_fn = admit_program(cfg, scfg, policy=policy)
        self.queue: Deque[Request] = deque()
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.caches: Caches = init_caches(cfg, slots, max_len)
        self.state: SlotState = init_slot_state(slots)
        self.stats = BatcherStats(cache_bytes=tree_bytes(self.caches))
        self._key = jax.random.PRNGKey(0)

    # -- request intake ------------------------------------------------
    def submit(self, req: Request) -> None:
        assert req.prompt.shape[0] <= self.prompt_len
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    # -- mid-run migration (Hypervisor resize between chunks) -----------
    def live_state(self) -> Dict[str, Any]:
        """Current device state, for ``TwoStageCompiler.reconfigure``
        migration.  Pull-only: the returned arrays are donated (dead) after
        the next step — register this *method* (not its result) with
        ``ServingExecutor.register_state``."""
        return {"caches": self.caches, "slots": self.state}

    def adopt_state(self, state: Dict[str, Any]) -> None:
        """Adopt a migrated state tree; decode resumes at the same token."""
        self.caches = state["caches"]
        self.state = state["slots"]

    # -- admission: right-sized prefill + per-slot scatter ---------------
    def _admit(self) -> None:
        free = self._free_slots()
        if not free or not self.queue:
            return
        joins = []
        while free and self.queue:
            joins.append((free.pop(0), self.queue.popleft()))
        n = len(joins)
        nb = min(1 << (n - 1).bit_length() if n > 1 else 1, self.B)
        toks = np.zeros((nb, self.prompt_len), dtype=np.int32)
        slots = np.zeros((nb,), dtype=np.int32)
        budget = np.zeros((nb,), dtype=np.int32)
        eos = np.full((nb,), -1, dtype=np.int32)
        for j, (slot, req) in enumerate(joins):
            p = req.prompt
            toks[j, self.prompt_len - len(p):] = p   # left-pad with 0s
            slots[j] = slot
            budget[j] = req.max_new
            if req.eos is not None:
                eos[j] = req.eos
        # pad a partial bucket by repeating row 0: duplicate-index scatters
        # then write identical values, which is deterministic
        for j in range(n, nb):
            toks[j] = toks[0]
            slots[j] = slots[0]
            budget[j] = budget[0]
            eos[j] = eos[0]
        pos0 = np.full((nb,), self.prompt_len, dtype=np.int32)
        nxt, self.caches, self.state = self._admit_fn(
            self.params, {"tokens": jnp.asarray(toks)}, self.caches,
            self.state, jnp.asarray(slots), jnp.asarray(pos0),
            jnp.asarray(budget), jnp.asarray(eos),
        )
        self.stats.prefills += 1
        self.stats.dispatches += 1
        self.stats.admit_scatter_bytes += int(
            self.stats.cache_bytes * nb / max(self.B, 1)
        )
        nxt_np = np.asarray(nxt)
        self.stats.host_syncs += 1
        for j, (slot, req) in enumerate(joins):
            tok = int(nxt_np[j])
            req.out.append(tok)
            self.stats.admit_tokens += 1
            hit_eos = req.eos is not None and tok == req.eos
            if len(req.out) >= req.max_new or hit_eos:
                req.done = True
                self.stats.completed += 1
            else:
                self.slot_req[slot] = req

    # -- chunk sizing: adaptive to queue pressure ------------------------
    def _pick_chunk(self, active: List[int]) -> int:
        """Queue pressure → short chunks (the earliest completion bounds
        admission latency); dry queue → chunks up to the longest remaining
        budget.  Sizes snap to power-of-two buckets (bounded jit cache)."""
        rem = [self.slot_req[i].max_new - len(self.slot_req[i].out)
               for i in active]
        horizon = min(rem) if self.queue else max(rem)
        return chunk_bucket(max(1, min(horizon, self.chunk)))

    def _chunk_fn(self, n_steps: int) -> Callable:
        return decode_chunk_program(self.cfg, self.scfg, n_steps,
                                    policy=self._policy)

    # -- one scheduling round: admit, then decode one chunk ---------------
    def step(self) -> None:
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        T = self._pick_chunk(active)
        self._key, sub = jax.random.split(self._key)
        self.caches, self.state, toks, emitted = self._chunk_fn(T)(
            self.params, self.caches, self.state, sub
        )
        self.stats.chunks += 1
        self.stats.dispatches += 1
        self.stats.steps += T
        toks_np, emit_np = jax.device_get((toks, emitted))   # ONE host sync
        self.stats.host_syncs += 1
        self.stats.slot_total_steps += self.B * T
        self.stats.slot_busy_steps += int(emit_np.sum())
        for i in active:
            req = self.slot_req[i]
            for t in range(T):
                if not emit_np[t, i]:
                    break
                req.out.append(int(toks_np[t, i]))
                self.stats.decode_tokens += 1
            hit_eos = req.eos is not None and req.out and \
                req.out[-1] == req.eos
            if len(req.out) >= req.max_new or hit_eos:
                req.done = True
                self.slot_req[i] = None
                self.stats.completed += 1

    def run(self, *, max_steps: int = 10_000) -> BatcherStats:
        while (self.queue or any(r is not None for r in self.slot_req)) and \
                self.stats.steps < max_steps:
            self.step()
        return self.stats
