"""Cell construction: (arch × shape × mesh) → lowered-ready program + args.

A *cell* bundles everything the dry-run, roofline, and launchers need:

    program          the step callable (train_step / prefill_step / serve_step)
    abstract_args    ShapeDtypeStruct stand-ins (no allocation)
    in_shardings     NamedShardings per arg
    donate_argnums   buffers reused in place (params/opt for train, caches
                     for decode) — affects the memory analysis, as on HW
    model_flops      6·N_active·D (train) or 2·N_active·D (inference) for
                     the useful-FLOPs ratio in §Roofline

The same builder powers reduced smoke cells (tests) and full dry-run cells.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ShapeConfig, get_config, get_reduced, SHAPES
from repro.data.synthetic import VLM_PATCHES, VLM_PATCHES_REDUCED
from repro.distributed.sharding import (
    batch_shard, cache_specs, make_policy, param_specs, train_batch_specs,
)
from repro.models import init_caches, init_params
from repro.optim import adamw_init, opt_state_specs
from repro.serving.engine import ServeConfig, make_prefill_step, make_serve_step
from repro.training.steps import TrainerConfig, make_train_step

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    mesh: Mesh
    program: Callable
    abstract_args: Tuple
    in_shardings: Tuple
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    model_flops: float
    cfg: Any
    note: str = ""

    def jitted(self):
        return jax.jit(
            self.program,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )

    def lower(self):
        with self.mesh:
            return self.jitted().lower(*self.abstract_args)


def trainer_defaults(cfg, shape: ShapeConfig, *, attn_impl: str = "xla",
                     remat: str = "full") -> TrainerConfig:
    big = cfg.param_count() > 40e9
    return TrainerConfig(
        quantize_opt=big,
        remat=remat,
        loss_chunk=128,
        attn_impl=attn_impl,
    )


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def abstract_batch(cfg, shape: ShapeConfig, *, with_labels: bool,
                   reduced: bool = False) -> Dict[str, SDS]:
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    n_patch = 0
    if cfg.family == "vlm":
        n_patch = VLM_PATCHES_REDUCED if reduced else VLM_PATCHES
    d: Dict[str, SDS] = {"tokens": SDS((B, S - n_patch), jnp.int32)}
    if with_labels:
        d["labels"] = SDS((B, S), jnp.int32)
    if cfg.family == "vlm":
        d["extra_embeds"] = SDS((B, n_patch, cfg.d_model), dt)
        d["positions"] = SDS((3, B, S), jnp.int32)
    if cfg.family == "audio":
        d["frames"] = SDS((B, cfg.enc_seq, cfg.d_model), dt)
    return d


def _abstract_params(cfg):
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


def build_cell(
    arch: str, shape_name: str, mesh: Mesh, *, reduced: bool = False,
    tcfg: Optional[TrainerConfig] = None, attn_impl: str = "xla",
    remat: str = "full", fsdp: bool = True, moe_ep: bool = True,
) -> Cell:
    cfg = get_reduced(arch) if reduced else get_config(arch)
    shape = SHAPES[shape_name]
    if reduced:
        shape = dataclasses.replace(
            shape, seq_len=min(shape.seq_len, 64),
            global_batch=min(shape.global_batch, 4),
        )
    B, S = shape.global_batch, shape.seq_len
    ba = batch_shard(mesh, B)
    policy = make_policy(cfg, mesh, batch=B, moe_ep=moe_ep)
    p_specs = param_specs(cfg, mesh, fsdp=fsdp, moe_ep=moe_ep)
    p_sh = _ns(mesh, p_specs)
    params_abs = _abstract_params(cfg)
    n_active = cfg.param_count(active_only=True)

    if shape.kind == "train":
        tcfg = tcfg or trainer_defaults(cfg, shape, attn_impl=attn_impl, remat=remat)
        program = make_train_step(cfg, tcfg, policy=policy, mesh=mesh)
        opt_abs = jax.eval_shape(
            lambda p: adamw_init(p, quantize=tcfg.quantize_opt), params_abs
        )
        o_specs = opt_state_specs(
            p_specs, quantize=tcfg.quantize_opt, params=params_abs, mesh=mesh
        )
        o_sh = _ns(mesh, o_specs)
        batch_abs = abstract_batch(cfg, shape, with_labels=True, reduced=reduced)
        b_specs = train_batch_specs(cfg, mesh, batch=B)
        b_sh = _ns(mesh, {k: b_specs[k] for k in batch_abs})
        return Cell(
            arch=arch, shape=shape, mesh=mesh, program=program,
            abstract_args=(params_abs, opt_abs, batch_abs),
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1),
            model_flops=6.0 * n_active * shape.tokens,
            cfg=cfg,
        )

    scfg = ServeConfig(max_len=S, attn_impl=attn_impl)
    c_specs = cache_specs(cfg, mesh, batch=B)
    c_sh = _ns(mesh, c_specs)

    if shape.kind == "prefill":
        program = make_prefill_step(cfg, scfg, policy=policy)
        batch_abs = abstract_batch(cfg, shape, with_labels=False, reduced=reduced)
        b_specs = train_batch_specs(cfg, mesh, batch=B)
        b_sh = _ns(mesh, {k: b_specs[k] for k in batch_abs})
        logits_sh = NamedSharding(mesh, P(ba, "model" if cfg.vocab_padded % mesh.shape["model"] == 0 else None))
        return Cell(
            arch=arch, shape=shape, mesh=mesh, program=program,
            abstract_args=(params_abs, batch_abs),
            in_shardings=(p_sh, b_sh),
            out_shardings=(logits_sh, c_sh),
            donate_argnums=(),
            model_flops=2.0 * n_active * shape.tokens,
            cfg=cfg,
        )

    # decode: serve_step(params, tokens, caches, cur_pos, key)
    program = make_serve_step(cfg, scfg, policy=policy)
    caches_abs = jax.eval_shape(lambda: init_caches(cfg, B, S))
    tok_abs = SDS((B,), jnp.int32)
    pos_abs = SDS((B,), jnp.int32)
    key_abs = SDS((2,), jnp.uint32)
    tok_sh = NamedSharding(mesh, P(ba))
    logits_sh = NamedSharding(mesh, P(ba, "model" if cfg.vocab_padded % mesh.shape["model"] == 0 else None))
    return Cell(
        arch=arch, shape=shape, mesh=mesh, program=program,
        abstract_args=(params_abs, tok_abs, caches_abs, pos_abs, key_abs),
        in_shardings=(p_sh, tok_sh, c_sh, tok_sh, None),
        out_shardings=(tok_sh, logits_sh, c_sh),
        donate_argnums=(2,),
        model_flops=2.0 * n_active * B,
        cfg=cfg,
    )
