import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# This flag exists ONLY here — smoke tests and benches see the real device.

"""Multi-pod dry-run: prove every (arch × shape × mesh) cell lowers,
compiles, fits, and report its roofline inputs.

For each cell:
    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...,
                           donate_argnums=...).lower(*input_specs(arch))
        compiled = lowered.compile()
        memory_analysis / cost_analysis / collective bytes (HLO parse)

Artifacts land in experiments/dryrun/<arch>__<shape>__<mesh>.json; the
roofline benchmark and EXPERIMENTS.md tables read from there.

Usage:
    python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
    python -m repro.launch.dryrun --all            # every runnable cell, 1 pod
    python -m repro.launch.dryrun --all --multi-pod
    python -m repro.launch.dryrun --all --both     # 1-pod then 2-pod
"""

import argparse
import json
import time
import traceback


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, out_dir: str,
             skip_existing: bool = True, attn_impl: str = "xla",
             remat: str = "full", dp_tp=None, fsdp: bool = True,
             moe_ep: bool = True) -> dict:
    import jax

    from repro.configs import cell_status
    from repro.distributed.hlo_analysis import Roofline, cost_flops_bytes
    from repro.distributed.hlo_static import analyze_hlo
    from repro.launch.cells import build_cell
    from repro.launch.mesh import make_production_mesh

    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}.json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("ok"):
            print(f"[skip] {arch} {shape_name} {mesh_tag} (cached)")
            return rec

    runs, reason = cell_status(arch, shape_name)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "multi_pod": multi_pod, "ok": False,
    }
    if not runs:
        rec.update({"skipped": True, "reason": reason, "ok": True})
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[SKIP-by-design] {arch} {shape_name}: {reason}")
        return rec

    t_start = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod, dp_tp=dp_tp)
        cell = build_cell(arch, shape_name, mesh, attn_impl=attn_impl,
                          remat=remat, fsdp=fsdp, moe_ep=moe_ep)
        t0 = time.time()
        lowered = cell.lower()
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        flops_ca, nbytes_ca = cost_flops_bytes(cost)
        try:
            hlo = compiled.as_text()
        except Exception:
            hlo = lowered.as_text()
        chips = int(len(mesh.devices.flat))
        # Trip-count-aware static analysis of the per-device SPMD module.
        # cost_analysis() visits while bodies once — a scanned 28-layer model
        # reports ~1/28th of its FLOPs — so the roofline reads hlo_static
        # instead (cost_analysis kept in the record for reference).
        st = analyze_hlo(hlo)
        roof = Roofline(
            chips=chips,
            hlo_flops=st.flops * chips,
            hlo_bytes=st.bytes * chips,
            collective_bytes=st.collective_bytes * chips,
            model_flops=cell.model_flops,
        )
        mem_attrs = {}
        for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                mem_attrs[k] = int(v)
        # peak per-device estimate: args + temps - donated aliases, / devices
        per_dev = None
        if mem_attrs:
            tot = (mem_attrs.get("argument_size_in_bytes", 0)
                   + mem_attrs.get("temp_size_in_bytes", 0)
                   + mem_attrs.get("output_size_in_bytes", 0)
                   - mem_attrs.get("alias_size_in_bytes", 0))
            per_dev = tot / chips
        rec.update({
            "ok": True,
            "chips": chips,
            "lower_s": t1 - t0,
            "compile_s": t2 - t1,
            "memory": mem_attrs,
            "per_device_bytes": per_dev,
            "cost_analysis_flops": flops_ca,
            "cost_analysis_bytes": nbytes_ca,
            "static_per_device": {
                "flops": st.flops,
                "bytes": st.bytes,
                "collective_wire_bytes": st.collective_bytes,
                "collective_raw_bytes": st.raw_collective_bytes,
                "unknown_trip_counts": st.unknown_trip_counts,
            },
            "collectives": {
                "total_bytes": st.collective_bytes,
                "by_op_bytes": st.collective_by_op,
                "by_op_count": st.collective_count,
            },
            "roofline": roof.as_dict(),
        })
        coll_str = ", ".join(
            f"{op}:{cnt}x {st.collective_by_op.get(op, 0)/1e6:.1f}MB"
            for op, cnt in sorted(st.collective_count.items())
        )
        print(
            f"[ok] {arch} {shape_name} {mesh_tag}: "
            f"lower {t1-t0:.0f}s compile {t2-t1:.0f}s "
            f"per-dev {per_dev/2**30 if per_dev else -1:.2f} GiB "
            f"bound={roof.bound} frac={roof.roofline_fraction:.2f} "
            f"useful={roof.useful_flops_ratio:.2f} "
            f"coll=[{coll_str}]"
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update({"error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
        print(f"[FAIL] {arch} {shape_name} {mesh_tag}: {type(e).__name__}: {e}")
    rec["wall_s"] = time.time() - t_start
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true", help="run 1-pod and 2-pod")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true", help="ignore cached results")
    ap.add_argument("--attn-impl", default="xla")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--dp-tp", default=None,
                    help="override per-pod (data,model) split, e.g. 64,4")
    ap.add_argument("--no-fsdp", action="store_true",
                    help="serving layout: params TP-only (no data-axis shard)")
    ap.add_argument("--no-moe-ep", action="store_true",
                    help="expert-TP instead of expert-parallel MoE sharding")
    args = ap.parse_args()

    from repro.configs import ARCHS, SHAPES

    pairs = []
    archs = ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    for a in archs:
        for s in shapes:
            pairs.append((a, s))

    meshes = [args.multi_pod]
    if args.both:
        meshes = [False, True]

    n_fail = 0
    for mp in meshes:
        for a, s in pairs:
            dp_tp = tuple(int(x) for x in args.dp_tp.split(",")) if args.dp_tp else None
            rec = run_cell(a, s, multi_pod=mp, out_dir=args.out,
                           skip_existing=not args.force,
                           attn_impl=args.attn_impl, remat=args.remat,
                           dp_tp=dp_tp, fsdp=not args.no_fsdp,
                           moe_ep=not args.no_moe_ep)
            if not rec.get("ok"):
                n_fail += 1
    print(f"dryrun finished: {n_fail} failures")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
