"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
        --steps 50 --batch 8 --seq 128

Production use (real TPU pod): drop --reduced; the mesh comes from
``make_production_mesh`` and jax.distributed initializes from the TPU
environment.  On this CPU container the reduced path trains a ~100M-class
model for a few hundred steps (examples/train_lm.py drives it).

Fault tolerance: async checkpointing every ``--ckpt-every`` steps; on start
the latest checkpoint under --ckpt-dir is restored (elastic: the restore
re-lays-out arrays for whatever mesh is active).  Simulated preemption via
--die-at-step proves restartability in tests/examples.
"""

from __future__ import annotations

import argparse
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="warmup_cosine")
    ap.add_argument("--remat", default="none", choices=["none", "dots", "full"])
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--loss-chunk", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--die-at-step", type=int, default=None,
                    help="simulate preemption: exit(42) after this step")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.checkpoint.store import AsyncCheckpointer, latest_step, restore
    from repro.configs import get_config, get_reduced
    from repro.data.synthetic import make_batch
    from repro.models import init_params
    from repro.optim import adamw_init
    from repro.training.steps import TrainerConfig, make_train_step

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    tcfg = TrainerConfig(
        lr=args.lr, schedule=args.schedule, warmup=max(args.steps // 10, 1),
        total_steps=args.steps, remat=args.remat, grad_accum=args.grad_accum,
        loss_chunk=args.loss_chunk,
    )
    print(f"[train] arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = adamw_init(params)
    start_step = 0
    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        like = {
            "params": jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params),
            "opt": jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), opt),
        }
        got = restore(args.ckpt_dir, like)
        params, opt = got["params"], got["opt"]
        start_step = latest_step(args.ckpt_dir)
        print(f"[train] restored checkpoint at step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
    t0 = time.time()
    tokens_done = 0
    for step in range(start_step, args.steps):
        batch_np = make_batch(cfg, seq_len=args.seq, batch=args.batch,
                              step=step, seed=args.seed, reduced=args.reduced)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        params, opt, m = step_fn(params, opt, batch)
        tokens_done += args.batch * args.seq
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.2f}  lr {float(m['lr']):.2e}  "
                  f"tok/s {tokens_done/max(dt,1e-9):,.0f}")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save_async(step + 1, {"params": params, "opt": opt},
                            metadata={"arch": cfg.name})
        if args.die_at_step is not None and step + 1 >= args.die_at_step:
            if ckpt:
                ckpt.wait()
            print(f"[train] simulated preemption at step {step + 1}")
            return 42
    if ckpt:
        ckpt.save_async(args.steps, {"params": params, "opt": opt},
                        metadata={"arch": cfg.name})
        ckpt.wait()
    print(f"[train] done: final loss {float(m['loss']):.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
