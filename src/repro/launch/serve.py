"""Serving launcher: multi-tenant virtualized inference on one "FPGA node".

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --tenants 2 --requests 16

Each tenant leases a disjoint core set from the VirtualAcceleratorPool
(SDM — the paper's isolation model), runs a ContinuousBatcher over its own
compiled programs, and can be resized at runtime through the TwoStageCompiler
without recompilation.  Decode runs the chunked/donated hot path (one device
dispatch and one host sync per --chunk tokens; see serving.batcher).  On
this CPU container cores are logical (1 device time-shared); on a real
slice each core is a chip/sub-mesh.
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=8,
                    help="decode steps fused per device dispatch (1 = per-step)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax

    from repro.configs import get_config, get_reduced
    from repro.models import init_params
    from repro.serving import ServingConfig
    from repro.serving.batcher import ContinuousBatcher, Request
    from repro.serving.tenancy import VirtualAcceleratorPool

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    pool = VirtualAcceleratorPool(devices=list(jax.devices()) * max(16, args.tenants),
                                  devices_per_core=1)
    rng = np.random.default_rng(args.seed)

    print(f"[serve] arch={cfg.name} tenants={args.tenants} "
          f"pool={pool.n_cores} cores")
    total_toks = 0
    t0 = time.time()
    for t in range(args.tenants):
        lease = pool.lease(f"tenant{t}", pool.n_cores // args.tenants)
        batcher = ContinuousBatcher(
            params, cfg,
            ServingConfig(slots=args.slots, prompt_len=args.prompt_len,
                          max_len=args.prompt_len + args.max_new + 2,
                          chunk=args.chunk),
        )
        for r in range(args.requests):
            plen = int(rng.integers(2, args.prompt_len))
            batcher.submit(Request(
                rid=r, prompt=rng.integers(1, cfg.vocab, size=plen).astype(np.int32),
                max_new=args.max_new,
            ))
        stats = batcher.run()
        print(f"  tenant{t}: lease={list(lease.cores)[:4]}..., "
              f"completed={stats.completed}/{args.requests}, "
              f"decode steps={stats.steps} in {stats.chunks} chunks "
              f"({stats.dispatches} dispatches, {stats.host_syncs} syncs, "
              f"{stats.dispatches_per_token:.3f} disp/token), "
              f"occupancy={stats.occupancy:.2f}")
        total_toks += stats.tokens
    dt = time.time() - t0
    print(f"[serve] done in {dt:.1f}s (~{total_toks/dt:,.0f} tokens/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
