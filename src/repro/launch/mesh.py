"""Production mesh builders.

Importing this module never touches JAX device state; meshes are built
inside functions only (the dry-run sets the 512-device XLA flag before any
jax import, and smoke tests must keep seeing 1 device).
"""

from __future__ import annotations


def make_mesh_compat(shape, axes, *, devices=None):
    """``jax.make_mesh`` with Auto axis types where the jax version supports
    them (``axis_types`` and ``jax.sharding.AxisType`` only exist on newer
    jax; older versions default to Auto/GSPMD propagation anyway)."""
    import jax

    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, devices=devices,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    return jax.make_mesh(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False, dp_tp=None):
    """16×16 = 256 chips per pod; 2 pods = 512 chips with a leading "pod"
    axis.  Axis types are Auto (GSPMD sharding propagation).

    ``dp_tp=(dp, tp)`` overrides the per-pod (data, model) split while
    keeping 256 chips/pod — the §Perf mesh-ratio knob (e.g. (64, 4) cuts the
    TP all-reduce wire ~4x for dense models; see EXPERIMENTS.md §Perf)."""
    import jax
    import numpy as np

    dp, tp = dp_tp if dp_tp is not None else (16, 16)
    assert dp * tp == 256, (dp, tp)
    shape = (2, dp, tp) if multi_pod else (dp, tp)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} "
            "(dryrun.py must set --xla_force_host_platform_device_count=512 "
            "before importing jax)"
        )
    return make_mesh_compat(shape, axes, devices=devices[:n])


def make_host_mesh(shape=None, axes=("data", "model")):
    """Small mesh over whatever devices exist — tests and examples."""
    import jax
    import numpy as np

    devices = jax.devices()
    if shape is None:
        shape = (1, len(devices))
    n = int(np.prod(shape))
    return make_mesh_compat(shape, axes, devices=devices[:n])
